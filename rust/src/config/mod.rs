//! Engine / experiment configuration.
//!
//! Three layers of config compose a run:
//!   * `ModelConfig`   — which transformer (paper-scale spec or the tiny
//!     real model the CPU engine executes);
//!   * `HardwareConfig`— which node profile (4090/A800 × cards) for the
//!     simulator, or `CpuThreads` for the real engine;
//!   * `EngineConfig`  — overlap strategy, split policy, quantization,
//!     chunking, batching, topology.
//!
//! `EngineConfig` keeps its flat fields (every call site reads them
//! directly) but is *viewed and built* through grouped sub-structs —
//! [`Topology`], [`OverlapCfg`], [`WireCfg`], [`SloCfg`], [`FaultCfg`] —
//! via [`EngineConfig::builder`], so every cross-field invariant lives in
//! one place ([`EngineConfig::validate`]). Config files address the
//! grouped keys (`topology.cp`, `slo.kv_offload`, …); the historical flat
//! `engine.*` keys stay accepted as deprecated aliases with byte-identical
//! defaults, pinned by the round-trip tests below.
//!
//! A small line-based config-file format (`key = value`, `#` comments,
//! `[section]` headers) replaces TOML in the offline build; presets cover
//! every paper experiment so files are optional.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::str::FromStr;

use crate::hw::NodeProfile;
use crate::model::ModelSpec;

/// Typed parse error for config enums and the `--topology` grammar: what
/// was being parsed, the offending spelling, and the accepted spellings.
/// `Display` renders the same `bad <what> <got>` shape the config-file
/// errors always had, now uniformly suffixed with the valid values.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigParseError {
    /// Which knob failed to parse (`"strategy"`, `"topology"`, …).
    pub what: &'static str,
    /// The rejected input, verbatim.
    pub got: String,
    /// Human-readable list of accepted spellings.
    pub valid: &'static str,
}

impl ConfigParseError {
    fn new(what: &'static str, got: &str, valid: &'static str) -> Self {
        ConfigParseError { what, got: got.to_string(), valid }
    }
}

impl fmt::Display for ConfigParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad {} {:?} (valid: {})", self.what, self.got, self.valid)
    }
}

impl std::error::Error for ConfigParseError {}

/// Which overlap strategy the scheduler runs (paper Fig 1 a–d).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// (a) original serial pipeline: compute → comm → compute → comm.
    Serial,
    /// (b) GEMM overlap: tile o_proj/down into the collective (T3/Flux-like).
    GemmOverlap,
    /// (c) request-level overlap: two requests ping-pong compute/comm (Liger).
    RequestOverlap,
    /// (d) ISO: two intra-sequence chunks overlap (the paper's contribution).
    Iso,
}

impl Strategy {
    /// Every strategy, in the paper's Fig-1 order.
    pub fn all() -> [Strategy; 4] {
        [Strategy::Serial, Strategy::GemmOverlap, Strategy::RequestOverlap, Strategy::Iso]
    }

    /// Parse a CLI/config spelling (`iso`, `serial`, `gemm-overlap`, …).
    /// Thin wrapper over the [`FromStr`] impl, kept for call-site brevity.
    pub fn parse(s: &str) -> Option<Strategy> {
        s.parse().ok()
    }
}

impl FromStr for Strategy {
    type Err = ConfigParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Ok(Strategy::Serial),
            "gemm" | "gemm-overlap" | "gemm_overlap" => Ok(Strategy::GemmOverlap),
            "request" | "request-overlap" | "request_overlap" => Ok(Strategy::RequestOverlap),
            "iso" => Ok(Strategy::Iso),
            _ => Err(ConfigParseError::new(
                "strategy",
                s,
                "serial, gemm-overlap, request-overlap, iso",
            )),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Strategy::Serial => "serial",
            Strategy::GemmOverlap => "gemm-overlap",
            Strategy::RequestOverlap => "request-overlap",
            Strategy::Iso => "iso",
        };
        write!(f, "{s}")
    }
}

/// How ISO picks the intra-sequence split point (paper §3.2/§6 + Fig 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitPolicy {
    /// 50/50 token split.
    Even,
    /// Fixed fraction for the first chunk (e.g. 0.6 → 60/40, paper §6).
    Ratio(f64),
    /// Solve for the split equalizing *time* of the two chunks, accounting
    /// for the causal-attention imbalance (second half is heavier).
    AttnBalanced,
    /// Fig 3: additionally rebalance attention vs MLP across micro-batches.
    AdaptiveAttnMlp,
}

impl SplitPolicy {
    /// Parse a CLI/config spelling (`even`, `balanced`, `ratio:0.6`, …).
    /// Thin wrapper over the [`FromStr`] impl, kept for call-site brevity.
    pub fn parse(s: &str) -> Option<SplitPolicy> {
        s.parse().ok()
    }
}

impl FromStr for SplitPolicy {
    type Err = ConfigParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let ls = s.to_ascii_lowercase();
        match ls.as_str() {
            "even" => Ok(SplitPolicy::Even),
            "balanced" | "attn-balanced" => Ok(SplitPolicy::AttnBalanced),
            "adaptive" | "attn-mlp" => Ok(SplitPolicy::AdaptiveAttnMlp),
            _ => ls
                .strip_prefix("ratio:")
                .and_then(|r| r.parse::<f64>().ok())
                .filter(|r| (0.05..=0.95).contains(r))
                .map(SplitPolicy::Ratio)
                .ok_or_else(|| {
                    ConfigParseError::new(
                        "split",
                        s,
                        "even, attn-balanced, attn-mlp, ratio:R with R in [0.05, 0.95]",
                    )
                }),
        }
    }
}

impl fmt::Display for SplitPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SplitPolicy::Even => write!(f, "even"),
            SplitPolicy::Ratio(r) => write!(f, "ratio:{r}"),
            SplitPolicy::AttnBalanced => write!(f, "attn-balanced"),
            SplitPolicy::AdaptiveAttnMlp => write!(f, "attn-mlp"),
        }
    }
}

/// Wire format of the tensor-parallel collectives — the precision
/// ladder, top to bottom (DESIGN.md §16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommQuant {
    /// fp16 activations on the wire (A800 default).
    Fp16,
    /// int8 + per-row scales (4090 default, paper §3.2).
    Int8,
    /// f32 (the CPU engine's native dtype; no quant).
    F32,
    /// fp8 e5m2, software-emulated, elementwise (no scale vector).
    Fp8,
    /// int4 packed nibbles + per-row scales.
    Int4,
}

impl CommQuant {
    /// Parse a CLI/config spelling (`f32`, `fp16`, `int8`, `fp8`, `int4`).
    /// Thin wrapper over the [`FromStr`] impl, kept for call-site brevity.
    pub fn parse(s: &str) -> Option<CommQuant> {
        s.parse().ok()
    }

    /// Engine wire bytes of a `rows × cols` f32 payload at this rung, as
    /// the ring actually moves it (`collective::Wire::bytes`): fp16 is
    /// modeled on the CPU testbed (raw f32 travels), int8/int4 add
    /// 4 bytes/row of scales, int4 packs two nibbles per byte per row.
    pub fn wire_bytes(self, rows: usize, cols: usize) -> usize {
        match self {
            CommQuant::F32 | CommQuant::Fp16 => rows * cols * 4,
            CommQuant::Int8 => rows * 4 + rows * cols,
            CommQuant::Fp8 => rows * cols,
            CommQuant::Int4 => rows * 4 + rows * cols.div_ceil(2),
        }
    }

    /// Every rung, ladder order (full → coarsest) — sweep/report order.
    pub const LADDER: [CommQuant; 5] =
        [CommQuant::F32, CommQuant::Fp16, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4];

    /// Stable position in [`CommQuant::LADDER`] — the index of the
    /// per-rung wire-byte counters (`WorkerStats::wire_bytes_by_rung`,
    /// `EngineMetrics::comm_bytes_by_rung`).
    pub fn index(self) -> usize {
        match self {
            CommQuant::F32 => 0,
            CommQuant::Fp16 => 1,
            CommQuant::Int8 => 2,
            CommQuant::Fp8 => 3,
            CommQuant::Int4 => 4,
        }
    }

    /// Canonical lowercase spelling (accepted back by
    /// [`CommQuant::parse`]) for reports and bench case names.
    pub fn label(self) -> &'static str {
        match self {
            CommQuant::F32 => "f32",
            CommQuant::Fp16 => "fp16",
            CommQuant::Int8 => "int8",
            CommQuant::Fp8 => "fp8",
            CommQuant::Int4 => "int4",
        }
    }

    /// Whether the rung re-encodes the payload below fp16 (lossy on the
    /// engine's f32 wire). The TBT-budget cost model prices every
    /// quantized rung at the int8 wire factor — conservative for
    /// fp8/int4, which move fewer bytes still.
    pub fn is_quantized(self) -> bool {
        matches!(self, CommQuant::Int8 | CommQuant::Fp8 | CommQuant::Int4)
    }
}

impl FromStr for CommQuant {
    type Err = ConfigParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fp16" | "f16" => Ok(CommQuant::Fp16),
            "int8" | "i8" => Ok(CommQuant::Int8),
            "f32" | "fp32" | "none" => Ok(CommQuant::F32),
            "fp8" | "f8" | "e5m2" => Ok(CommQuant::Fp8),
            "int4" | "i4" => Ok(CommQuant::Int4),
            _ => Err(ConfigParseError::new("wire rung", s, "f32, fp16, int8, fp8, int4")),
        }
    }
}

impl fmt::Display for CommQuant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Per-phase wire-precision policy (DESIGN.md §16): which ladder rung
/// prefill collectives use, and which — usually lower — rung the fused
/// decode/verify lane uses. Decode-lane activations tolerate a coarser
/// wire than prefill logits (one token's drift vs a whole prompt's),
/// which is the ladder's whole point: resolve via
/// [`EngineConfig::precision`], never from `comm_quant` directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPolicy {
    /// Rung for prefill (and every other non-lane) collective.
    pub prefill: CommQuant,
    /// Rung for fused decode/verify-lane collectives.
    pub decode: CommQuant,
}

/// Number of segments the pre-collective GEMM is split into when compute
/// dominates (paper §3.2 "computation dominates": multiple kernel launches
/// so compute reclaims the SMs the moment comm ends).
pub const DEFAULT_GEMM_SEGMENTS: usize = 4;

/// The engine's rank grid, as one value: `pp` pipeline stages × `tp`
/// tensor-parallel ranks per stage × `cp` context-parallel groups
/// (DESIGN.md §17). The canonical CLI spelling is `ppP.tpT.cpC`
/// (e.g. `pp2.tp2.cp1`); axes omitted from the string keep their
/// defaults, so `tp4` alone means `pp1.tp4.cp1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Pipeline-parallel stage count (flat field `pp_stages`).
    pub pp: usize,
    /// Tensor-parallel width per stage (flat field `tp`).
    pub tp: usize,
    /// Context-parallel group count (flat field `cp`).
    pub cp: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { pp: 1, tp: 2, cp: 1 }
    }
}

impl Topology {
    /// Total worker ranks the engine spawns: `pp × tp × cp`.
    pub fn world(&self) -> usize {
        self.pp * self.tp * self.cp
    }
}

impl FromStr for Topology {
    type Err = ConfigParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        const VALID: &str = "ppP.tpT.cpC, e.g. pp2.tp2.cp1 (axes may be omitted)";
        let mut t = Topology::default();
        if s.trim().is_empty() {
            return Err(ConfigParseError::new("topology", s, VALID));
        }
        for part in s.to_ascii_lowercase().split('.') {
            let (axis, digits) = if let Some(d) = part.strip_prefix("pp") {
                (&mut t.pp, d)
            } else if let Some(d) = part.strip_prefix("tp") {
                (&mut t.tp, d)
            } else if let Some(d) = part.strip_prefix("cp") {
                (&mut t.cp, d)
            } else {
                return Err(ConfigParseError::new("topology", s, VALID));
            };
            *axis = digits
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| ConfigParseError::new("topology", s, VALID))?;
        }
        Ok(t)
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pp{}.tp{}.cp{}", self.pp, self.tp, self.cp)
    }
}

/// Grouped view of the overlap/scheduling knobs (config section
/// `[overlap]`). Mirrors the flat `EngineConfig` fields of the same
/// names — see those for full semantics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapCfg {
    /// Overlap strategy (paper Fig 1 a–d).
    pub strategy: Strategy,
    /// ISO intra-sequence split policy.
    pub split: SplitPolicy,
    /// Segments for the computation-dominates mitigation (1 = off).
    pub gemm_segments: usize,
    /// Row-segments each engine collective is streamed in.
    pub comm_segments: usize,
    /// Max chunk length the engine schedules.
    pub max_chunk: usize,
    /// Iteration-level mixed scheduling in `serve_trace`.
    pub mixed_iterations: bool,
    /// Width cap of the fused decode lane per mixed iteration.
    pub decode_batch: usize,
    /// Run the decode lane's MLP as one B-row GEMM when compiled.
    pub lane_gemm: bool,
    /// Fused post-collective epilogue (DESIGN.md §12).
    pub fused_epilogue: bool,
    /// Ladder-residual reordering (numerics-changing, opt-in).
    pub ladder_residual: bool,
    /// Speculative-decoding draft count per lane sequence (0 = off).
    pub spec_k: usize,
    /// N-gram order of the self-draft proposer.
    pub spec_ngram: usize,
}

impl Default for OverlapCfg {
    fn default() -> Self {
        OverlapCfg {
            strategy: Strategy::Iso,
            split: SplitPolicy::AttnBalanced,
            gemm_segments: DEFAULT_GEMM_SEGMENTS,
            comm_segments: 1,
            max_chunk: 64,
            mixed_iterations: true,
            decode_batch: 8,
            lane_gemm: true,
            fused_epilogue: true,
            ladder_residual: false,
            spec_k: 0,
            spec_ngram: 2,
        }
    }
}

/// Grouped view of the wire knobs (config section `[wire]`): base rung,
/// per-phase overrides, and the emulated link. Mirrors the flat
/// `EngineConfig` fields of the same names.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireCfg {
    /// Wire format of the ring collectives.
    pub comm_quant: CommQuant,
    /// Override rung for *all* collectives (`wire.precision`).
    pub wire_precision: Option<CommQuant>,
    /// Override rung for the fused decode/verify lane only.
    pub decode_wire_precision: Option<CommQuant>,
    /// Emulated wire bandwidth (MB/s); `None` = full memory speed.
    pub link_mbps: Option<f64>,
    /// Emulated per-hop latency (µs) when `link_mbps` is set.
    pub link_alpha_us: f64,
}

impl Default for WireCfg {
    fn default() -> Self {
        WireCfg {
            comm_quant: CommQuant::F32,
            wire_precision: None,
            decode_wire_precision: None,
            link_mbps: None,
            link_alpha_us: 50.0,
        }
    }
}

/// Grouped view of the SLO / memory-pressure knobs (config section
/// `[slo]`), including the cold-KV offload tier added with context
/// parallelism (DESIGN.md §17). Mirrors the flat `EngineConfig` fields.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloCfg {
    /// Per-iteration decode-TBT budget (ms); `0.0` = off.
    pub tbt_budget_ms: f64,
    /// Paged-KV high-water mark in `(0, 1]`; `1.0` = no preemption.
    pub kv_high_water: f64,
    /// Admission queue bound; `0` = unbounded.
    pub queue_bound: usize,
    /// Preemptions allowed per sequence (anti-livelock cap).
    pub max_preemptions: usize,
    /// TTFT shedding deadline (ms); `0.0` = off.
    pub ttft_deadline_ms: f64,
    /// Cold-KV offload: spill least-recently-needed pages to the host
    /// tier instead of failing when the resident pool fills.
    pub kv_offload: bool,
    /// Resident-pool cap in tokens (`0` = uncapped, the whole pool).
    pub kv_resident_tokens: usize,
    /// Pages prefetched ahead of the decode cursor (`0` = none).
    pub kv_prefetch_pages: usize,
}

impl Default for SloCfg {
    fn default() -> Self {
        SloCfg {
            tbt_budget_ms: 0.0,
            kv_high_water: 1.0,
            queue_bound: 0,
            max_preemptions: 2,
            ttft_deadline_ms: 0.0,
            kv_offload: false,
            kv_resident_tokens: 0,
            kv_prefetch_pages: 2,
        }
    }
}

/// Grouped view of the fault-tolerance knobs (config section `[fault]`).
/// Mirrors the flat `EngineConfig` fields (`fault_plan`, `fault_slack`,
/// `deadline_floor_ms`, `max_recoveries`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultCfg {
    /// Seeded deterministic fault plan; `None` = fault-free.
    pub plan: Option<String>,
    /// Detection-deadline slack over the per-iteration EMA.
    pub slack: f64,
    /// Floor (ms) under the deadline EMA.
    pub deadline_floor_ms: f64,
    /// Mesh respawns attempted before giving up.
    pub max_recoveries: usize,
}

impl Default for FaultCfg {
    fn default() -> Self {
        FaultCfg { plan: None, slack: 32.0, deadline_floor_ms: 250.0, max_recoveries: 4 }
    }
}

/// Full engine configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Overlap strategy (paper Fig 1 a–d).
    pub strategy: Strategy,
    /// ISO intra-sequence split policy.
    pub split: SplitPolicy,
    /// Wire format of the ring collectives.
    pub comm_quant: CommQuant,
    /// Segments for the computation-dominates mitigation (1 = off).
    pub gemm_segments: usize,
    /// Row-segments each engine collective is streamed in (1 = one
    /// monolithic message per ring hop). The engine-side twin of the
    /// simulator's `Coster::ar_s(t, segments)` knob: higher values let
    /// the ring overlap transfer with reduction and ack partial results
    /// early, at the cost of more per-message latency (α).
    pub comm_segments: usize,
    /// Tensor-parallel degree for the real CPU engine. With pipeline
    /// stages this is the TP width *per stage*; the engine spawns
    /// `pp_stages × tp × cp` worker pairs in total.
    pub tp: usize,
    /// Pipeline-parallel stage count (DESIGN.md §11). `1` = the classic
    /// single-stage TP engine. With `pp_stages > 1` the model's layers
    /// are partitioned into contiguous stage groups (balanced via
    /// `seg_range`), each stage internally tensor-parallel over its own
    /// ring, stages connected by bit-exact point-to-point activation
    /// handoffs; ISO's sequence chunks double as pipeline micro-batches.
    pub pp_stages: usize,
    /// Context-parallel group count (DESIGN.md §17). `1` = classic
    /// behavior, byte-identical to the pre-CP engine. With `cp > 1`
    /// each group owns a contiguous KV shard of every prefill, the
    /// shards are chained group-to-group so prefill attention sees the
    /// exact prefix while later groups' layers overlap earlier groups'
    /// streaming, and decode runs CP-gathered on the last group
    /// (SNIPPETS.md snippet 2: "SP is not allowed" in decode).
    pub cp: usize,
    /// Max chunk length the engine schedules (must exist in artifacts).
    pub max_chunk: usize,
    /// Max concurrent sequences in a batch.
    pub max_batch: usize,
    /// Width cap of the fused decode lane in a mixed iteration (≥ 1):
    /// up to this many live sequences decode one token each per
    /// iteration, sharing one B-row all-reduce per layer-stage.
    pub decode_batch: usize,
    /// Iteration-level mixed scheduling in `serve_trace` (DESIGN.md §9):
    /// each iteration composes the head-of-line prefill's ISO chunks with
    /// the fused decode lane. `false` = legacy per-request loop (prefill
    /// then round-robin single-token decodes) for A/B comparison.
    pub mixed_iterations: bool,
    /// Run the decode lane's MLP as one B-row GEMM when that width is
    /// compiled. Escape hatch: disable if a backend's B-row kernel is not
    /// bit-stable against per-row execution (lane *collectives* stay
    /// fused either way).
    pub lane_gemm: bool,
    /// Fused post-collective epilogue (DESIGN.md §12): collectives carry
    /// their residual tensor to the comm thread, which applies each
    /// reduced row-segment into it the moment the segment finalizes —
    /// TokenWeave-style — so the residual-add overlaps the collective's
    /// in-flight tail instead of running serially after it. Bit-exact to
    /// the unfused path (same adds, same order per element; pinned by
    /// `rust/tests/fused_epilogue.rs`). `false` = legacy per-segment acks
    /// applied on the compute thread, kept for A/B comparison.
    pub fused_epilogue: bool,
    /// Ladder-residual reordering (DESIGN.md §12, **numerics-changing**,
    /// opt-in): in the per-sequence blocking layer loops (serial-strategy
    /// prefill and legacy per-sequence decode) the MLP reads the
    /// *pre-attention* residual so both block collectives are in flight
    /// while it computes, and the two reduced partials fold in
    /// back-to-back. Changes activations (the model was not trained with
    /// this dataflow), so it is excluded from every bit-exact pin and off
    /// by default. The fused decode/verify lanes and the ISO/mixed
    /// schedules ignore it — the lanes so iteration composition never
    /// changes a sequence's math, the ISO interleave because it already
    /// fills those windows.
    pub ladder_residual: bool,
    /// Speculative decoding (DESIGN.md §10): draft tokens verified per
    /// lane sequence per iteration. `0` = off (the one-token decode
    /// lane); `k > 0` widens each lane entry into a `k + 1`-row verify
    /// window whose collectives stay fused, so a decode iteration can
    /// advance a sequence by up to `k + 1` tokens. Greedy verification
    /// keeps emitted tokens identical to `spec_k = 0`.
    pub spec_k: usize,
    /// N-gram order of the built-in self-draft proposer
    /// (`batch::NGramProposer`); only read when `spec_k > 0`.
    pub spec_ngram: usize,
    /// Decode steps to run per request after prefill (0 = prefill only).
    pub decode_steps: usize,
    /// Artifact directory for the real engine.
    pub artifacts_dir: String,
    /// Emulated wire bandwidth for the ring (MB/s). `None` = full memory
    /// speed. Throttling reproduces the paper's compute:comm ratios on the
    /// CPU testbed (DESIGN.md §2); the int8 wire then genuinely shrinks
    /// the transfer time, like the 4090's fp16→int8 compression.
    pub link_mbps: Option<f64>,
    /// Emulated per-hop latency (µs) when `link_mbps` is set.
    pub link_alpha_us: f64,
    /// Seeded deterministic fault plan (`fault::FaultPlan` grammar, e.g.
    /// `"kill:rank=1:iter=3"`), `None` = fault-free. Parsed eagerly so a
    /// typo fails at startup, not mid-serve (DESIGN.md §14).
    pub fault_plan: Option<String>,
    /// Detection-deadline slack: the leader waits `fault_slack ×` the
    /// observed per-iteration EMA before declaring a rank dead. Large by
    /// default so scheduler jitter on a loaded CI box never trips a
    /// false positive (false positives are safe — recovery preserves
    /// bit-identity — just slow).
    pub fault_slack: f64,
    /// Floor (ms) under the deadline EMA, covering cold starts and
    /// compilation pauses before the EMA has samples.
    pub deadline_floor_ms: f64,
    /// Mesh respawns the engine will attempt before giving up and
    /// surfacing the fault to the caller.
    pub max_recoveries: usize,
    /// Per-iteration decode-TBT budget (ms) enforced by bounding how
    /// many prefill chunks the mixed planner admits per iteration
    /// (DESIGN.md §15). `0.0` disables the bound: whole prompts prefill
    /// in one iteration, exactly the pre-overload behavior. Requires
    /// `cp = 1` — budget slices do not compose with sharded prefill.
    pub tbt_budget_ms: f64,
    /// Paged-KV high-water mark as a fraction of the pool in `(0, 1]`.
    /// When used blocks exceed it, the engine preempts the youngest
    /// prefilled sequence to free pages. `1.0` disables preemption
    /// (usage can never exceed the whole pool).
    pub kv_high_water: f64,
    /// Admission queue bound; requests beyond it are rejected with
    /// `EngineError::Overloaded` instead of queueing without limit.
    /// `0` = unbounded (pre-overload behavior).
    pub queue_bound: usize,
    /// Preemptions allowed per sequence before it becomes unevictable;
    /// the anti-livelock cap of DESIGN.md §15.
    pub max_preemptions: usize,
    /// TTFT deadline (ms): queued requests that have already waited
    /// longer are shed at admission time rather than served late.
    /// `0.0` disables shedding.
    pub ttft_deadline_ms: f64,
    /// Wire-precision override for *all* collectives (`--wire-precision`,
    /// DESIGN.md §16). `None` = use `comm_quant`, byte-identical to the
    /// pre-ladder engine.
    pub wire_precision: Option<CommQuant>,
    /// Wire-precision override for the fused decode/verify lane only
    /// (`--decode-wire-precision`). `None` = same rung as prefill.
    pub decode_wire_precision: Option<CommQuant>,
    /// Cold-KV offload (DESIGN.md §17): when the paged pool's resident
    /// cap is exceeded, spill the pages farthest behind the decode
    /// cursor to a modeled host tier and prefetch them back ahead of
    /// the cursor, instead of failing allocation. `false` = resident
    /// pool only (a prompt that cannot fit fails with a typed error).
    pub kv_offload: bool,
    /// Resident-pool cap in *tokens* for the offload model (`0` =
    /// uncapped: the whole pool stays resident and offload never
    /// triggers, byte-identical to the pre-offload engine).
    pub kv_resident_tokens: usize,
    /// KV pages prefetched ahead of the decode cursor per step when
    /// offload is on (`0` = demand-fetch only).
    pub kv_prefetch_pages: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            strategy: Strategy::Iso,
            split: SplitPolicy::AttnBalanced,
            comm_quant: CommQuant::F32,
            gemm_segments: DEFAULT_GEMM_SEGMENTS,
            comm_segments: 1,
            tp: 2,
            pp_stages: 1,
            cp: 1,
            max_chunk: 64,
            max_batch: 8,
            decode_batch: 8,
            mixed_iterations: true,
            lane_gemm: true,
            fused_epilogue: true,
            ladder_residual: false,
            spec_k: 0,
            spec_ngram: 2,
            decode_steps: 0,
            artifacts_dir: "artifacts".into(),
            link_mbps: None,
            link_alpha_us: 50.0,
            fault_plan: None,
            fault_slack: 32.0,
            deadline_floor_ms: 250.0,
            max_recoveries: 4,
            tbt_budget_ms: 0.0,
            kv_high_water: 1.0,
            queue_bound: 0,
            max_preemptions: 2,
            ttft_deadline_ms: 0.0,
            wire_precision: None,
            decode_wire_precision: None,
            kv_offload: false,
            kv_resident_tokens: 0,
            kv_prefetch_pages: 2,
        }
    }
}

impl EngineConfig {
    /// Resolve the per-phase precision policy: `wire_precision` (else
    /// `comm_quant`) for prefill, `decode_wire_precision` (else the
    /// prefill rung) for the fused decode/verify lane.
    pub fn precision(&self) -> PrecisionPolicy {
        let prefill = self.wire_precision.unwrap_or(self.comm_quant);
        let decode = self.decode_wire_precision.unwrap_or(prefill);
        PrecisionPolicy { prefill, decode }
    }

    /// The rank grid as one value (`pp × tp × cp`).
    pub fn topology(&self) -> Topology {
        Topology { pp: self.pp_stages, tp: self.tp, cp: self.cp }
    }

    /// Grouped view of the overlap/scheduling knobs.
    pub fn overlap(&self) -> OverlapCfg {
        OverlapCfg {
            strategy: self.strategy,
            split: self.split,
            gemm_segments: self.gemm_segments,
            comm_segments: self.comm_segments,
            max_chunk: self.max_chunk,
            mixed_iterations: self.mixed_iterations,
            decode_batch: self.decode_batch,
            lane_gemm: self.lane_gemm,
            fused_epilogue: self.fused_epilogue,
            ladder_residual: self.ladder_residual,
            spec_k: self.spec_k,
            spec_ngram: self.spec_ngram,
        }
    }

    /// Grouped view of the wire knobs.
    pub fn wire(&self) -> WireCfg {
        WireCfg {
            comm_quant: self.comm_quant,
            wire_precision: self.wire_precision,
            decode_wire_precision: self.decode_wire_precision,
            link_mbps: self.link_mbps,
            link_alpha_us: self.link_alpha_us,
        }
    }

    /// Grouped view of the SLO / memory-pressure knobs.
    pub fn slo(&self) -> SloCfg {
        SloCfg {
            tbt_budget_ms: self.tbt_budget_ms,
            kv_high_water: self.kv_high_water,
            queue_bound: self.queue_bound,
            max_preemptions: self.max_preemptions,
            ttft_deadline_ms: self.ttft_deadline_ms,
            kv_offload: self.kv_offload,
            kv_resident_tokens: self.kv_resident_tokens,
            kv_prefetch_pages: self.kv_prefetch_pages,
        }
    }

    /// Grouped view of the fault-tolerance knobs.
    pub fn fault(&self) -> FaultCfg {
        FaultCfg {
            plan: self.fault_plan.clone(),
            slack: self.fault_slack,
            deadline_floor_ms: self.deadline_floor_ms,
            max_recoveries: self.max_recoveries,
        }
    }

    /// A validating builder over the grouped sub-structs; the one
    /// front door for constructing a checked config in code.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder { cfg: EngineConfig::default() }
    }
}

/// Builder over [`EngineConfig`]'s grouped sub-structs. Starts from the
/// defaults, takes whole groups ([`Topology`], [`OverlapCfg`], …) plus
/// the few run-level scalars, and runs every cross-field invariant in
/// [`EngineConfig::validate`] at [`EngineConfigBuilder::build`] — the
/// config-file path (`from_map`) funnels through the same validation,
/// so an invariant holds everywhere or nowhere.
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    /// Set the rank grid (`pp × tp × cp`).
    pub fn topology(mut self, t: Topology) -> Self {
        self.cfg.pp_stages = t.pp;
        self.cfg.tp = t.tp;
        self.cfg.cp = t.cp;
        self
    }

    /// Set the overlap/scheduling group.
    pub fn overlap(mut self, o: OverlapCfg) -> Self {
        self.cfg.strategy = o.strategy;
        self.cfg.split = o.split;
        self.cfg.gemm_segments = o.gemm_segments;
        self.cfg.comm_segments = o.comm_segments;
        self.cfg.max_chunk = o.max_chunk;
        self.cfg.mixed_iterations = o.mixed_iterations;
        self.cfg.decode_batch = o.decode_batch;
        self.cfg.lane_gemm = o.lane_gemm;
        self.cfg.fused_epilogue = o.fused_epilogue;
        self.cfg.ladder_residual = o.ladder_residual;
        self.cfg.spec_k = o.spec_k;
        self.cfg.spec_ngram = o.spec_ngram;
        self
    }

    /// Set the wire group.
    pub fn wire(mut self, w: WireCfg) -> Self {
        self.cfg.comm_quant = w.comm_quant;
        self.cfg.wire_precision = w.wire_precision;
        self.cfg.decode_wire_precision = w.decode_wire_precision;
        self.cfg.link_mbps = w.link_mbps;
        self.cfg.link_alpha_us = w.link_alpha_us;
        self
    }

    /// Set the SLO / memory-pressure group.
    pub fn slo(mut self, s: SloCfg) -> Self {
        self.cfg.tbt_budget_ms = s.tbt_budget_ms;
        self.cfg.kv_high_water = s.kv_high_water;
        self.cfg.queue_bound = s.queue_bound;
        self.cfg.max_preemptions = s.max_preemptions;
        self.cfg.ttft_deadline_ms = s.ttft_deadline_ms;
        self.cfg.kv_offload = s.kv_offload;
        self.cfg.kv_resident_tokens = s.kv_resident_tokens;
        self.cfg.kv_prefetch_pages = s.kv_prefetch_pages;
        self
    }

    /// Set the fault-tolerance group.
    pub fn fault(mut self, f: FaultCfg) -> Self {
        self.cfg.fault_plan = f.plan;
        self.cfg.fault_slack = f.slack;
        self.cfg.deadline_floor_ms = f.deadline_floor_ms;
        self.cfg.max_recoveries = f.max_recoveries;
        self
    }

    /// Max concurrent sequences in a batch (run-level scalar).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Decode steps per request after prefill (run-level scalar).
    pub fn decode_steps(mut self, n: usize) -> Self {
        self.cfg.decode_steps = n;
        self
    }

    /// Artifact directory for the real engine (run-level scalar).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Validate every cross-field invariant and return the config.
    pub fn build(self) -> Result<EngineConfig, String> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// Parse the line-based config format:
/// ```text
/// [topology]
/// tp = 4
/// [overlap]
/// strategy = iso
/// ```
pub fn parse_config_file(path: &Path) -> Result<BTreeMap<String, String>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    parse_config_str(&text)
}

/// Keys are returned as `section.key` (or bare `key` before any section).
pub fn parse_config_str(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

/// The accepted boolean spellings for config keys and CLI flags alike.
pub fn parse_bool(v: &str, key: &str) -> Result<bool, String> {
    match v {
        "true" | "on" | "1" => Ok(true),
        "false" | "off" | "0" => Ok(false),
        _ => Err(format!("bad {key} {v:?}")),
    }
}

impl EngineConfig {
    /// Build from parsed `section.key` pairs; unknown keys are errors so
    /// typos don't silently fall back to defaults. Accepts the grouped
    /// canonical keys (`topology.tp`, `overlap.strategy`, `wire.precision`,
    /// `slo.kv_offload`, `fault.plan`, …) and, as deprecated aliases with
    /// identical semantics, the historical flat `engine.*` spellings.
    pub fn from_map(map: &BTreeMap<String, String>) -> Result<Self, String> {
        let mut cfg = EngineConfig::default();
        for (k, v) in map {
            match k.as_str() {
                "engine.strategy" | "overlap.strategy" => {
                    cfg.strategy = v.parse::<Strategy>().map_err(|e| e.to_string())?
                }
                "engine.split" | "overlap.split" => {
                    cfg.split = v.parse::<SplitPolicy>().map_err(|e| e.to_string())?
                }
                "engine.comm_quant" | "wire.comm_quant" => {
                    cfg.comm_quant =
                        CommQuant::parse(v).ok_or_else(|| format!("bad comm_quant {v:?}"))?
                }
                "engine.gemm_segments" | "overlap.gemm_segments" => {
                    cfg.gemm_segments = v.parse().map_err(|_| format!("bad gemm_segments {v:?}"))?
                }
                "engine.comm_segments" | "overlap.comm_segments" => {
                    cfg.comm_segments = v.parse().map_err(|_| format!("bad comm_segments {v:?}"))?
                }
                "engine.tp" | "topology.tp" => {
                    cfg.tp = v.parse().map_err(|_| format!("bad tp {v:?}"))?
                }
                "engine.pp_stages" | "topology.pp" => {
                    cfg.pp_stages = v.parse().map_err(|_| format!("bad pp_stages {v:?}"))?
                }
                "topology.cp" => cfg.cp = v.parse().map_err(|_| format!("bad cp {v:?}"))?,
                "engine.max_chunk" | "overlap.max_chunk" => {
                    cfg.max_chunk = v.parse().map_err(|_| format!("bad max_chunk {v:?}"))?
                }
                "engine.max_batch" => {
                    cfg.max_batch = v.parse().map_err(|_| format!("bad max_batch {v:?}"))?
                }
                "engine.decode_batch" | "overlap.decode_batch" => {
                    cfg.decode_batch =
                        v.parse().map_err(|_| format!("bad decode_batch {v:?}"))?
                }
                "engine.mixed_iterations" | "overlap.mixed_iterations" => {
                    cfg.mixed_iterations = parse_bool(v, "mixed_iterations")?
                }
                "engine.lane_gemm" | "overlap.lane_gemm" => {
                    cfg.lane_gemm = parse_bool(v, "lane_gemm")?
                }
                "engine.fused_epilogue" | "overlap.fused_epilogue" => {
                    cfg.fused_epilogue = parse_bool(v, "fused_epilogue")?
                }
                "engine.ladder_residual" | "overlap.ladder_residual" => {
                    cfg.ladder_residual = parse_bool(v, "ladder_residual")?
                }
                "engine.spec_k" | "overlap.spec_k" => {
                    cfg.spec_k = v.parse().map_err(|_| format!("bad spec_k {v:?}"))?
                }
                "engine.spec_ngram" | "overlap.spec_ngram" => {
                    cfg.spec_ngram = v.parse().map_err(|_| format!("bad spec_ngram {v:?}"))?
                }
                "engine.decode_steps" => {
                    cfg.decode_steps = v.parse().map_err(|_| format!("bad decode_steps {v:?}"))?
                }
                "engine.artifacts_dir" => cfg.artifacts_dir = v.clone(),
                "engine.link_mbps" | "wire.link_mbps" => {
                    cfg.link_mbps =
                        Some(v.parse().map_err(|_| format!("bad link_mbps {v:?}"))?)
                }
                "engine.link_alpha_us" | "wire.link_alpha_us" => {
                    cfg.link_alpha_us = v.parse().map_err(|_| format!("bad link_alpha_us {v:?}"))?
                }
                "engine.fault_plan" | "fault.plan" => cfg.fault_plan = Some(v.clone()),
                "engine.fault_slack" | "fault.slack" => {
                    cfg.fault_slack = v.parse().map_err(|_| format!("bad fault_slack {v:?}"))?
                }
                "engine.deadline_floor_ms" | "fault.deadline_floor_ms" => {
                    cfg.deadline_floor_ms =
                        v.parse().map_err(|_| format!("bad deadline_floor_ms {v:?}"))?
                }
                "engine.max_recoveries" | "fault.max_recoveries" => {
                    cfg.max_recoveries =
                        v.parse().map_err(|_| format!("bad max_recoveries {v:?}"))?
                }
                "engine.tbt_budget_ms" | "slo.tbt_budget_ms" => {
                    cfg.tbt_budget_ms =
                        v.parse().map_err(|_| format!("bad tbt_budget_ms {v:?}"))?
                }
                "engine.kv_high_water" | "slo.kv_high_water" => {
                    cfg.kv_high_water =
                        v.parse().map_err(|_| format!("bad kv_high_water {v:?}"))?
                }
                "engine.queue_bound" | "slo.queue_bound" => {
                    cfg.queue_bound = v.parse().map_err(|_| format!("bad queue_bound {v:?}"))?
                }
                "engine.max_preemptions" | "slo.max_preemptions" => {
                    cfg.max_preemptions =
                        v.parse().map_err(|_| format!("bad max_preemptions {v:?}"))?
                }
                "engine.ttft_deadline_ms" | "slo.ttft_deadline_ms" => {
                    cfg.ttft_deadline_ms =
                        v.parse().map_err(|_| format!("bad ttft_deadline_ms {v:?}"))?
                }
                "engine.wire_precision" | "wire.precision" => {
                    cfg.wire_precision = Some(
                        CommQuant::parse(v).ok_or_else(|| format!("bad wire_precision {v:?}"))?,
                    )
                }
                "engine.decode_wire_precision" | "wire.decode_precision" => {
                    cfg.decode_wire_precision = Some(
                        CommQuant::parse(v)
                            .ok_or_else(|| format!("bad decode_wire_precision {v:?}"))?,
                    )
                }
                "slo.kv_offload" => cfg.kv_offload = parse_bool(v, "kv_offload")?,
                "slo.kv_resident_tokens" => {
                    cfg.kv_resident_tokens =
                        v.parse().map_err(|_| format!("bad kv_resident_tokens {v:?}"))?
                }
                "slo.kv_prefetch_pages" => {
                    cfg.kv_prefetch_pages =
                        v.parse().map_err(|_| format!("bad kv_prefetch_pages {v:?}"))?
                }
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Every cross-field invariant, in one place: called by `from_map`
    /// and [`EngineConfigBuilder::build`] alike. Invariants needing the
    /// model manifest (`pp_stages ≤ n_layers`, chunk sizes compiled)
    /// stay in `Engine::start`, which sees the artifacts.
    pub fn validate(&self) -> Result<(), String> {
        if self.gemm_segments == 0 {
            return Err("gemm_segments must be >= 1".into());
        }
        if self.comm_segments == 0 {
            return Err("comm_segments must be >= 1".into());
        }
        if self.decode_batch == 0 {
            return Err("decode_batch must be >= 1".into());
        }
        if self.spec_ngram == 0 {
            return Err("spec_ngram must be >= 1".into());
        }
        if self.tp == 0 {
            return Err("tp must be >= 1".into());
        }
        if self.pp_stages == 0 {
            return Err("pp_stages must be >= 1".into());
        }
        if self.cp == 0 {
            return Err("cp must be >= 1".into());
        }
        if self.fault_slack < 1.0 {
            return Err("fault_slack must be >= 1".into());
        }
        if self.tbt_budget_ms < 0.0 {
            return Err("tbt_budget_ms must be >= 0".into());
        }
        if self.cp > 1 && self.tbt_budget_ms > 0.0 {
            return Err("tbt_budget_ms requires cp = 1 (bounded chunked prefill \
                 does not compose with context parallelism)"
                .into());
        }
        if !(self.kv_high_water > 0.0 && self.kv_high_water <= 1.0) {
            return Err("kv_high_water must be in (0, 1]".into());
        }
        if self.ttft_deadline_ms < 0.0 {
            return Err("ttft_deadline_ms must be >= 0".into());
        }
        if let Some(plan) = &self.fault_plan {
            // Parse eagerly so a typo'd plan fails at startup.
            crate::fault::FaultPlan::parse(plan).map_err(|e| format!("bad fault_plan: {e}"))?;
        }
        Ok(())
    }

    /// Re-emit the config as its canonical `section.key` map — the
    /// fixed point of `from_map ∘ to_map` (pinned by the round-trip
    /// property test). `None`-valued options are omitted, exactly as an
    /// untouched config file leaves them unset.
    pub fn to_map(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: String| {
            m.insert(k.to_string(), v);
        };
        put("topology.pp", self.pp_stages.to_string());
        put("topology.tp", self.tp.to_string());
        put("topology.cp", self.cp.to_string());
        put("overlap.strategy", self.strategy.to_string());
        put("overlap.split", self.split.to_string());
        put("overlap.gemm_segments", self.gemm_segments.to_string());
        put("overlap.comm_segments", self.comm_segments.to_string());
        put("overlap.max_chunk", self.max_chunk.to_string());
        put("overlap.mixed_iterations", self.mixed_iterations.to_string());
        put("overlap.decode_batch", self.decode_batch.to_string());
        put("overlap.lane_gemm", self.lane_gemm.to_string());
        put("overlap.fused_epilogue", self.fused_epilogue.to_string());
        put("overlap.ladder_residual", self.ladder_residual.to_string());
        put("overlap.spec_k", self.spec_k.to_string());
        put("overlap.spec_ngram", self.spec_ngram.to_string());
        put("wire.comm_quant", self.comm_quant.to_string());
        if let Some(p) = self.wire_precision {
            put("wire.precision", p.to_string());
        }
        if let Some(p) = self.decode_wire_precision {
            put("wire.decode_precision", p.to_string());
        }
        if let Some(mbps) = self.link_mbps {
            put("wire.link_mbps", mbps.to_string());
        }
        put("wire.link_alpha_us", self.link_alpha_us.to_string());
        put("slo.tbt_budget_ms", self.tbt_budget_ms.to_string());
        put("slo.kv_high_water", self.kv_high_water.to_string());
        put("slo.queue_bound", self.queue_bound.to_string());
        put("slo.max_preemptions", self.max_preemptions.to_string());
        put("slo.ttft_deadline_ms", self.ttft_deadline_ms.to_string());
        put("slo.kv_offload", self.kv_offload.to_string());
        put("slo.kv_resident_tokens", self.kv_resident_tokens.to_string());
        put("slo.kv_prefetch_pages", self.kv_prefetch_pages.to_string());
        if let Some(plan) = &self.fault_plan {
            put("fault.plan", plan.clone());
        }
        put("fault.slack", self.fault_slack.to_string());
        put("fault.deadline_floor_ms", self.deadline_floor_ms.to_string());
        put("fault.max_recoveries", self.max_recoveries.to_string());
        put("engine.max_batch", self.max_batch.to_string());
        put("engine.decode_steps", self.decode_steps.to_string());
        put("engine.artifacts_dir", self.artifacts_dir.clone());
        m
    }
}

/// A fully-specified simulator experiment (one Table-1 cell).
#[derive(Clone, Debug)]
pub struct SimExperiment {
    /// Modeled node (device × cards × interconnect).
    pub node: NodeProfile,
    /// Modeled transformer geometry.
    pub model: ModelSpec,
    /// Prefill prompt length.
    pub prompt_len: usize,
    /// Overlap strategy under test.
    pub strategy: Strategy,
    /// ISO split policy.
    pub split: SplitPolicy,
    /// Whether collectives quantize to int8 on the wire.
    pub int8_wire: bool,
    /// Launches the pre-collective GEMMs are segmented into.
    pub gemm_segments: usize,
}

impl SimExperiment {
    /// An experiment with the node's default wire format and balanced split.
    pub fn new(node: NodeProfile, model: ModelSpec, prompt_len: usize, strategy: Strategy) -> Self {
        let int8_wire = node.int8_wire_default;
        SimExperiment {
            node,
            model,
            prompt_len,
            strategy,
            split: SplitPolicy::AttnBalanced,
            int8_wire,
            gemm_segments: DEFAULT_GEMM_SEGMENTS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::parse(&s.to_string()), Some(s));
        }
        assert_eq!(Strategy::parse("GEMM-OVERLAP"), Some(Strategy::GemmOverlap));
        assert!(Strategy::parse("magic").is_none());
    }

    #[test]
    fn split_policy_parse() {
        assert_eq!(SplitPolicy::parse("even"), Some(SplitPolicy::Even));
        assert_eq!(SplitPolicy::parse("ratio:0.6"), Some(SplitPolicy::Ratio(0.6)));
        assert_eq!(SplitPolicy::parse("balanced"), Some(SplitPolicy::AttnBalanced));
        assert_eq!(SplitPolicy::parse("adaptive"), Some(SplitPolicy::AdaptiveAttnMlp));
        assert!(SplitPolicy::parse("ratio:1.5").is_none());
        assert!(SplitPolicy::parse("ratio:abc").is_none());
    }

    #[test]
    fn from_str_errors_list_valid_values() {
        // The typed error carries what/got/valid and renders them all;
        // CLI and config-file paths surface the same message.
        let e = "magic".parse::<Strategy>().unwrap_err();
        assert_eq!(e.what, "strategy");
        assert_eq!(e.got, "magic");
        assert!(e.to_string().contains("bad strategy \"magic\""), "{e}");
        assert!(e.to_string().contains("iso"), "{e}");
        let e = "ratio:1.5".parse::<SplitPolicy>().unwrap_err();
        assert!(e.to_string().contains("ratio:R"), "{e}");
        let e = "int2".parse::<CommQuant>().unwrap_err();
        assert!(e.to_string().contains("f32, fp16, int8, fp8, int4"), "{e}");
        let e = "pp2.xx3".parse::<Topology>().unwrap_err();
        assert!(e.to_string().contains("ppP.tpT.cpC"), "{e}");
    }

    #[test]
    fn topology_parses_and_displays() {
        let t: Topology = "pp2.tp2.cp1".parse().unwrap();
        assert_eq!(t, Topology { pp: 2, tp: 2, cp: 1 });
        assert_eq!(t.world(), 4);
        assert_eq!(t.to_string(), "pp2.tp2.cp1");
        // Omitted axes keep their defaults (pp 1, tp 2, cp 1).
        assert_eq!("tp4".parse::<Topology>().unwrap(), Topology { pp: 1, tp: 4, cp: 1 });
        assert_eq!("cp2.tp2".parse::<Topology>().unwrap(), Topology { pp: 1, tp: 2, cp: 2 });
        // Display round-trips through parse.
        for t in [Topology::default(), Topology { pp: 4, tp: 1, cp: 3 }] {
            assert_eq!(t.to_string().parse::<Topology>().unwrap(), t);
        }
        assert!("".parse::<Topology>().is_err());
        assert!("pp".parse::<Topology>().is_err());
        assert!("pp0.tp2".parse::<Topology>().is_err());
        assert!("pp2,tp2".parse::<Topology>().is_err());
    }

    #[test]
    fn config_file_parsing() {
        let text = r#"
            # a comment
            [engine]
            strategy = iso       # trailing comment
            split = ratio:0.6
            tp = 4
            comm_quant = int8
            comm_segments = 4
            decode_batch = 4
            mixed_iterations = false
        "#;
        let map = parse_config_str(text).unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.strategy, Strategy::Iso);
        assert_eq!(cfg.split, SplitPolicy::Ratio(0.6));
        assert_eq!(cfg.tp, 4);
        assert_eq!(cfg.comm_quant, CommQuant::Int8);
        assert_eq!(cfg.comm_segments, 4);
        assert_eq!(cfg.decode_batch, 4);
        assert!(!cfg.mixed_iterations);
    }

    #[test]
    fn grouped_sections_parse_like_engine_aliases() {
        // The same knobs spelled through the canonical grouped sections.
        let text = r#"
            [topology]
            pp = 2
            tp = 4
            cp = 2
            [overlap]
            strategy = serial
            decode_batch = 4
            [wire]
            comm_quant = int8
            precision = fp8
            [slo]
            queue_bound = 64
            kv_offload = on
            [fault]
            slack = 8
        "#;
        let cfg = EngineConfig::from_map(&parse_config_str(text).unwrap()).unwrap();
        assert_eq!(cfg.topology(), Topology { pp: 2, tp: 4, cp: 2 });
        assert_eq!(cfg.strategy, Strategy::Serial);
        assert_eq!(cfg.decode_batch, 4);
        assert_eq!(cfg.comm_quant, CommQuant::Int8);
        assert_eq!(cfg.wire_precision, Some(CommQuant::Fp8));
        assert_eq!(cfg.queue_bound, 64);
        assert!(cfg.kv_offload);
        assert_eq!(cfg.fault_slack, 8.0);
    }

    #[test]
    fn every_engine_alias_equals_canonical() {
        // Each deprecated `engine.*` alias must produce a config equal
        // to its canonical grouped spelling.
        let pairs = [
            ("engine.strategy", "overlap.strategy", "serial"),
            ("engine.split", "overlap.split", "ratio:0.25"),
            ("engine.comm_quant", "wire.comm_quant", "int8"),
            ("engine.gemm_segments", "overlap.gemm_segments", "2"),
            ("engine.comm_segments", "overlap.comm_segments", "3"),
            ("engine.tp", "topology.tp", "4"),
            ("engine.pp_stages", "topology.pp", "2"),
            ("engine.max_chunk", "overlap.max_chunk", "32"),
            ("engine.decode_batch", "overlap.decode_batch", "4"),
            ("engine.mixed_iterations", "overlap.mixed_iterations", "false"),
            ("engine.lane_gemm", "overlap.lane_gemm", "off"),
            ("engine.fused_epilogue", "overlap.fused_epilogue", "off"),
            ("engine.ladder_residual", "overlap.ladder_residual", "on"),
            ("engine.spec_k", "overlap.spec_k", "4"),
            ("engine.spec_ngram", "overlap.spec_ngram", "3"),
            ("engine.link_mbps", "wire.link_mbps", "800"),
            ("engine.link_alpha_us", "wire.link_alpha_us", "25"),
            ("engine.fault_plan", "fault.plan", "kill:rank=1:iter=3"),
            ("engine.fault_slack", "fault.slack", "8"),
            ("engine.deadline_floor_ms", "fault.deadline_floor_ms", "100"),
            ("engine.max_recoveries", "fault.max_recoveries", "2"),
            ("engine.tbt_budget_ms", "slo.tbt_budget_ms", "50"),
            ("engine.kv_high_water", "slo.kv_high_water", "0.85"),
            ("engine.queue_bound", "slo.queue_bound", "64"),
            ("engine.max_preemptions", "slo.max_preemptions", "3"),
            ("engine.ttft_deadline_ms", "slo.ttft_deadline_ms", "500"),
            ("engine.wire_precision", "wire.precision", "fp8"),
            ("engine.decode_wire_precision", "wire.decode_precision", "int4"),
        ];
        for (alias, canonical, value) in pairs {
            let via = |key: &str| {
                let mut m = BTreeMap::new();
                m.insert(key.to_string(), value.to_string());
                EngineConfig::from_map(&m)
                    .unwrap_or_else(|e| panic!("{key} = {value}: {e}"))
            };
            assert_eq!(via(alias), via(canonical), "{alias} vs {canonical}");
            // And neither spelling may silently equal the default.
            assert_ne!(via(alias), EngineConfig::default(), "{alias} was a no-op");
        }
    }

    #[test]
    fn grouped_views_mirror_flat_fields() {
        let mut cfg = EngineConfig::default();
        assert_eq!(cfg.topology(), Topology::default());
        assert_eq!(cfg.overlap(), OverlapCfg::default());
        assert_eq!(cfg.wire(), WireCfg::default());
        assert_eq!(cfg.slo(), SloCfg::default());
        assert_eq!(cfg.fault(), FaultCfg::default());
        cfg.cp = 2;
        cfg.kv_offload = true;
        assert_eq!(cfg.topology().cp, 2);
        assert!(cfg.slo().kv_offload);
    }

    #[test]
    fn builder_defaults_equal_flat_defaults() {
        // Grouped construction is byte-identical to the flat defaults —
        // the golden pin for the deprecated alias layer.
        assert_eq!(EngineConfig::builder().build().unwrap(), EngineConfig::default());
        let built = EngineConfig::builder()
            .topology(Topology { pp: 2, tp: 2, cp: 1 })
            .slo(SloCfg { queue_bound: 64, ..Default::default() })
            .max_batch(4)
            .build()
            .unwrap();
        assert_eq!((built.pp_stages, built.tp, built.cp), (2, 2, 1));
        assert_eq!(built.queue_bound, 64);
        assert_eq!(built.max_batch, 4);
    }

    #[test]
    fn builder_centralizes_validation() {
        let bad = EngineConfig::builder()
            .slo(SloCfg { kv_high_water: 0.0, ..Default::default() })
            .build();
        assert_eq!(bad.unwrap_err(), "kv_high_water must be in (0, 1]");
        let bad = EngineConfig::builder().topology(Topology { pp: 0, tp: 2, cp: 1 }).build();
        assert_eq!(bad.unwrap_err(), "pp_stages must be >= 1");
        let bad = EngineConfig::builder().topology(Topology { pp: 1, tp: 0, cp: 1 }).build();
        assert_eq!(bad.unwrap_err(), "tp must be >= 1");
        let bad = EngineConfig::builder()
            .fault(FaultCfg { plan: Some("kill:rank=1".into()), ..Default::default() })
            .build();
        assert!(bad.unwrap_err().starts_with("bad fault_plan"));
    }

    #[test]
    fn cp_and_offload_knobs_default_off_and_validate() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.cp, 1, "context parallelism must be opt-in");
        assert!(!cfg.kv_offload, "offload must be opt-in");
        assert_eq!(cfg.kv_resident_tokens, 0, "uncapped resident pool by default");
        assert_eq!(cfg.kv_prefetch_pages, 2);

        let map = parse_config_str(
            "[topology]\ncp = 2\n[slo]\nkv_offload = on\n\
             kv_resident_tokens = 4096\nkv_prefetch_pages = 4",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.cp, 2);
        assert!(cfg.kv_offload);
        assert_eq!(cfg.kv_resident_tokens, 4096);
        assert_eq!(cfg.kv_prefetch_pages, 4);

        let bad = parse_config_str("[topology]\ncp = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        // Budget slices do not compose with sharded prefill.
        let bad = parse_config_str("[topology]\ncp = 2\n[slo]\ntbt_budget_ms = 50").unwrap();
        let err = EngineConfig::from_map(&bad).unwrap_err();
        assert!(err.contains("tbt_budget_ms requires cp = 1"), "{err}");
    }

    #[test]
    fn to_map_from_map_is_a_fixed_point() {
        // Deterministic spot check before the property run: defaults.
        let cfg = EngineConfig::default();
        let m = cfg.to_map();
        assert_eq!(EngineConfig::from_map(&m).unwrap(), cfg);
        assert_eq!(EngineConfig::from_map(&m).unwrap().to_map(), m);
        // None-valued options stay unset, not emitted as a spelling.
        assert!(!m.contains_key("wire.precision"));
        assert!(!m.contains_key("fault.plan"));
    }

    #[test]
    fn prop_config_round_trips_through_canonical_map() {
        Prop::new(0xC0FF).cases(128).run("map → config → map fixed point", |rng| {
            let cfg = random_config(rng);
            let m = cfg.to_map();
            let back = EngineConfig::from_map(&m).map_err(|e| format!("{m:?}: {e}"))?;
            if back != cfg {
                return Err(format!("config drifted: {cfg:?} vs {back:?}"));
            }
            if back.to_map() != m {
                return Err(format!("map drifted: {m:?} vs {:?}", back.to_map()));
            }
            Ok(())
        });
    }

    /// A random *valid* config exercising every field the map carries.
    fn random_config(rng: &mut Rng) -> EngineConfig {
        let strategies = Strategy::all();
        let splits = [
            SplitPolicy::Even,
            SplitPolicy::AttnBalanced,
            SplitPolicy::AdaptiveAttnMlp,
            SplitPolicy::Ratio(0.05 + rng.f64() * 0.9),
        ];
        let cp = rng.range(1, 4);
        EngineConfig {
            strategy: strategies[rng.range(0, strategies.len())],
            split: splits[rng.range(0, splits.len())],
            comm_quant: CommQuant::LADDER[rng.range(0, CommQuant::LADDER.len())],
            gemm_segments: rng.range(1, 8),
            comm_segments: rng.range(1, 4),
            tp: rng.range(1, 8),
            pp_stages: rng.range(1, 4),
            cp,
            max_chunk: 16 << rng.range(0, 4),
            max_batch: rng.range(1, 16),
            decode_batch: rng.range(1, 16),
            mixed_iterations: rng.below(2) == 0,
            lane_gemm: rng.below(2) == 0,
            fused_epilogue: rng.below(2) == 0,
            ladder_residual: rng.below(2) == 0,
            spec_k: rng.range(0, 5),
            spec_ngram: rng.range(1, 4),
            decode_steps: rng.range(0, 32),
            artifacts_dir: "artifacts".into(),
            link_mbps: if rng.below(2) == 0 { Some(rng.f64() * 1000.0 + 1.0) } else { None },
            link_alpha_us: rng.f64() * 100.0,
            fault_plan: if rng.below(4) == 0 { Some("kill:rank=1:iter=3".into()) } else { None },
            fault_slack: 1.0 + rng.f64() * 32.0,
            deadline_floor_ms: rng.f64() * 500.0,
            max_recoveries: rng.range(1, 8),
            tbt_budget_ms: if cp > 1 { 0.0 } else { rng.f64() * 100.0 },
            kv_high_water: 0.1 + rng.f64() * 0.9,
            queue_bound: rng.range(0, 128),
            max_preemptions: rng.range(1, 4),
            ttft_deadline_ms: rng.f64() * 1000.0,
            wire_precision: if rng.below(2) == 0 {
                Some(CommQuant::LADDER[rng.range(0, CommQuant::LADDER.len())])
            } else {
                None
            },
            decode_wire_precision: if rng.below(2) == 0 {
                Some(CommQuant::LADDER[rng.range(0, CommQuant::LADDER.len())])
            } else {
                None
            },
            kv_offload: rng.below(2) == 0,
            kv_resident_tokens: rng.range(0, 1 << 20),
            kv_prefetch_pages: rng.range(0, 8),
        }
    }

    #[test]
    fn mixed_batching_defaults_and_validation() {
        let cfg = EngineConfig::default();
        assert!(cfg.mixed_iterations);
        assert!(cfg.lane_gemm);
        assert_eq!(cfg.decode_batch, 8);
        let map = parse_config_str("[engine]\ndecode_batch = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\nmixed_iterations = maybe").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\nlane_gemm = off").unwrap();
        assert!(!EngineConfig::from_map(&map).unwrap().lane_gemm);
    }

    #[test]
    fn fused_epilogue_and_ladder_knobs() {
        let cfg = EngineConfig::default();
        assert!(cfg.fused_epilogue, "fused epilogue is the default path");
        assert!(!cfg.ladder_residual, "numerics-changing mode must be opt-in");
        let map = parse_config_str(
            "[engine]\nfused_epilogue = off\nladder_residual = on",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert!(!cfg.fused_epilogue);
        assert!(cfg.ladder_residual);
        let bad = parse_config_str("[engine]\nfused_epilogue = maybe").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nladder_residual = 2").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn spec_decode_knobs_parse_and_validate() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.spec_k, 0, "speculation must be opt-in");
        assert_eq!(cfg.spec_ngram, 2);
        let map = parse_config_str("[engine]\nspec_k = 4\nspec_ngram = 3").unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.spec_k, 4);
        assert_eq!(cfg.spec_ngram, 3);
        let bad = parse_config_str("[engine]\nspec_ngram = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nspec_k = many").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn pp_stages_parses_and_validates() {
        assert_eq!(EngineConfig::default().pp_stages, 1, "PP must be opt-in");
        let map = parse_config_str("[engine]\npp_stages = 2\ntp = 2").unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!((cfg.pp_stages, cfg.tp), (2, 2));
        let bad = parse_config_str("[engine]\npp_stages = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\npp_stages = two").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn comm_quant_ladder_parses() {
        assert_eq!(CommQuant::parse("fp8"), Some(CommQuant::Fp8));
        assert_eq!(CommQuant::parse("E5M2"), Some(CommQuant::Fp8));
        assert_eq!(CommQuant::parse("int4"), Some(CommQuant::Int4));
        assert_eq!(CommQuant::parse("i4"), Some(CommQuant::Int4));
        assert!(CommQuant::parse("int2").is_none());
    }

    #[test]
    fn precision_policy_defaults_to_comm_quant() {
        // Acceptance pin: with neither override set, the policy is
        // `comm_quant` on both phases — byte-identical pre-ladder
        // behavior, including the existing int8 opt-in.
        let mut cfg = EngineConfig::default();
        let p = cfg.precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::F32, CommQuant::F32));
        cfg.comm_quant = CommQuant::Int8;
        let p = cfg.precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Int8, CommQuant::Int8));
    }

    #[test]
    fn precision_policy_overrides_cascade() {
        let map = parse_config_str("[engine]\nwire_precision = fp8").unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Fp8, CommQuant::Fp8));

        // decode override alone lowers only the lane rung.
        let map = parse_config_str("[engine]\ndecode_wire_precision = int4").unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::F32, CommQuant::Int4));

        let map = parse_config_str(
            "[engine]\ncomm_quant = int8\nwire_precision = fp8\n\
             decode_wire_precision = int4",
        )
        .unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Fp8, CommQuant::Int4));

        let bad = parse_config_str("[engine]\nwire_precision = int2").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn comm_quant_wire_bytes_hand_arithmetic() {
        // The bytes columns of BENCH_PRECISION.json trace to this table.
        let (r, c) = (8, 17); // odd cols exercise the int4 ceil
        assert_eq!(CommQuant::F32.wire_bytes(r, c), 8 * 17 * 4);
        assert_eq!(CommQuant::Fp16.wire_bytes(r, c), 8 * 17 * 4); // modeled
        assert_eq!(CommQuant::Int8.wire_bytes(r, c), 8 * 4 + 8 * 17);
        assert_eq!(CommQuant::Fp8.wire_bytes(r, c), 8 * 17);
        assert_eq!(CommQuant::Int4.wire_bytes(r, c), 8 * 4 + 8 * 9);
    }

    #[test]
    fn unknown_key_rejected() {
        let map = parse_config_str("[engine]\nstrtegy = iso").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let map = parse_config_str("[engine]\ntp = four").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\ngemm_segments = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\ncomm_segments = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
    }

    #[test]
    fn missing_equals_is_error() {
        assert!(parse_config_str("[engine]\nstrategy iso").is_err());
    }

    #[test]
    fn fault_knobs_default_off_and_parse() {
        let cfg = EngineConfig::default();
        assert!(cfg.fault_plan.is_none(), "fault injection must be opt-in");
        assert!(cfg.fault_slack >= 1.0);
        assert!(cfg.deadline_floor_ms > 0.0);
        assert!(cfg.max_recoveries >= 1);

        let map = parse_config_str(
            "[engine]\nfault_plan = kill:rank=1:iter=3\nfault_slack = 8\n\
             deadline_floor_ms = 100\nmax_recoveries = 2",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.fault_plan.as_deref(), Some("kill:rank=1:iter=3"));
        assert_eq!(cfg.fault_slack, 8.0);
        assert_eq!(cfg.deadline_floor_ms, 100.0);
        assert_eq!(cfg.max_recoveries, 2);
    }

    #[test]
    fn fault_knobs_validated() {
        // A typo'd plan fails at parse time, not mid-serve.
        let bad = parse_config_str("[engine]\nfault_plan = kill:rank=1").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nfault_slack = 0.5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn overload_knobs_default_off_and_parse() {
        // Every overload knob defaults off: an unconfigured engine
        // behaves byte-identically to the pre-overload scheduler.
        let cfg = EngineConfig::default();
        assert_eq!(cfg.tbt_budget_ms, 0.0, "prefill bounding must be opt-in");
        assert_eq!(cfg.kv_high_water, 1.0, "preemption must be opt-in");
        assert_eq!(cfg.queue_bound, 0, "backpressure must be opt-in");
        assert_eq!(cfg.ttft_deadline_ms, 0.0, "shedding must be opt-in");
        assert!(cfg.max_preemptions >= 1);

        let map = parse_config_str(
            "[engine]\ntbt_budget_ms = 50\nkv_high_water = 0.85\n\
             queue_bound = 64\nmax_preemptions = 3\nttft_deadline_ms = 500",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.tbt_budget_ms, 50.0);
        assert_eq!(cfg.kv_high_water, 0.85);
        assert_eq!(cfg.queue_bound, 64);
        assert_eq!(cfg.max_preemptions, 3);
        assert_eq!(cfg.ttft_deadline_ms, 500.0);
    }

    #[test]
    fn overload_knobs_validated() {
        let bad = parse_config_str("[engine]\nkv_high_water = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nkv_high_water = 1.5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\ntbt_budget_ms = -1").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nttft_deadline_ms = -5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn experiment_inherits_node_wire_default() {
        use crate::hw::NodeProfile;
        use crate::model::ModelSpec;
        let e = SimExperiment::new(
            NodeProfile::rtx4090(4),
            ModelSpec::mha_30b(),
            4096,
            Strategy::Iso,
        );
        assert!(e.int8_wire);
        let e = SimExperiment::new(NodeProfile::a800(4), ModelSpec::gqa_70b(), 4096, Strategy::Iso);
        assert!(!e.int8_wire);
    }
}
