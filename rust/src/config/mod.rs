//! Engine / experiment configuration.
//!
//! Three layers of config compose a run:
//!   * `ModelConfig`   — which transformer (paper-scale spec or the tiny
//!     real model the CPU engine executes);
//!   * `HardwareConfig`— which node profile (4090/A800 × cards) for the
//!     simulator, or `CpuThreads` for the real engine;
//!   * `EngineConfig`  — overlap strategy, split policy, quantization,
//!     chunking, batching.
//!
//! A small line-based config-file format (`key = value`, `#` comments,
//! `[section]` headers) replaces TOML in the offline build; presets cover
//! every paper experiment so files are optional.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::hw::NodeProfile;
use crate::model::ModelSpec;

/// Which overlap strategy the scheduler runs (paper Fig 1 a–d).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// (a) original serial pipeline: compute → comm → compute → comm.
    Serial,
    /// (b) GEMM overlap: tile o_proj/down into the collective (T3/Flux-like).
    GemmOverlap,
    /// (c) request-level overlap: two requests ping-pong compute/comm (Liger).
    RequestOverlap,
    /// (d) ISO: two intra-sequence chunks overlap (the paper's contribution).
    Iso,
}

impl Strategy {
    /// Every strategy, in the paper's Fig-1 order.
    pub fn all() -> [Strategy; 4] {
        [Strategy::Serial, Strategy::GemmOverlap, Strategy::RequestOverlap, Strategy::Iso]
    }

    /// Parse a CLI/config spelling (`iso`, `serial`, `gemm-overlap`, …).
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Some(Strategy::Serial),
            "gemm" | "gemm-overlap" | "gemm_overlap" => Some(Strategy::GemmOverlap),
            "request" | "request-overlap" | "request_overlap" => Some(Strategy::RequestOverlap),
            "iso" => Some(Strategy::Iso),
            _ => None,
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Strategy::Serial => "serial",
            Strategy::GemmOverlap => "gemm-overlap",
            Strategy::RequestOverlap => "request-overlap",
            Strategy::Iso => "iso",
        };
        write!(f, "{s}")
    }
}

/// How ISO picks the intra-sequence split point (paper §3.2/§6 + Fig 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitPolicy {
    /// 50/50 token split.
    Even,
    /// Fixed fraction for the first chunk (e.g. 0.6 → 60/40, paper §6).
    Ratio(f64),
    /// Solve for the split equalizing *time* of the two chunks, accounting
    /// for the causal-attention imbalance (second half is heavier).
    AttnBalanced,
    /// Fig 3: additionally rebalance attention vs MLP across micro-batches.
    AdaptiveAttnMlp,
}

impl SplitPolicy {
    /// Parse a CLI/config spelling (`even`, `balanced`, `ratio:0.6`, …).
    pub fn parse(s: &str) -> Option<SplitPolicy> {
        let ls = s.to_ascii_lowercase();
        match ls.as_str() {
            "even" => Some(SplitPolicy::Even),
            "balanced" | "attn-balanced" => Some(SplitPolicy::AttnBalanced),
            "adaptive" | "attn-mlp" => Some(SplitPolicy::AdaptiveAttnMlp),
            _ => ls
                .strip_prefix("ratio:")
                .and_then(|r| r.parse::<f64>().ok())
                .filter(|r| (0.05..=0.95).contains(r))
                .map(SplitPolicy::Ratio),
        }
    }
}

/// Wire format of the tensor-parallel collectives — the precision
/// ladder, top to bottom (DESIGN.md §16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommQuant {
    /// fp16 activations on the wire (A800 default).
    Fp16,
    /// int8 + per-row scales (4090 default, paper §3.2).
    Int8,
    /// f32 (the CPU engine's native dtype; no quant).
    F32,
    /// fp8 e5m2, software-emulated, elementwise (no scale vector).
    Fp8,
    /// int4 packed nibbles + per-row scales.
    Int4,
}

impl CommQuant {
    /// Parse a CLI/config spelling (`f32`, `fp16`, `int8`, `fp8`, `int4`).
    pub fn parse(s: &str) -> Option<CommQuant> {
        match s.to_ascii_lowercase().as_str() {
            "fp16" | "f16" => Some(CommQuant::Fp16),
            "int8" | "i8" => Some(CommQuant::Int8),
            "f32" | "fp32" | "none" => Some(CommQuant::F32),
            "fp8" | "f8" | "e5m2" => Some(CommQuant::Fp8),
            "int4" | "i4" => Some(CommQuant::Int4),
            _ => None,
        }
    }

    /// Engine wire bytes of a `rows × cols` f32 payload at this rung, as
    /// the ring actually moves it (`collective::Wire::bytes`): fp16 is
    /// modeled on the CPU testbed (raw f32 travels), int8/int4 add
    /// 4 bytes/row of scales, int4 packs two nibbles per byte per row.
    pub fn wire_bytes(self, rows: usize, cols: usize) -> usize {
        match self {
            CommQuant::F32 | CommQuant::Fp16 => rows * cols * 4,
            CommQuant::Int8 => rows * 4 + rows * cols,
            CommQuant::Fp8 => rows * cols,
            CommQuant::Int4 => rows * 4 + rows * cols.div_ceil(2),
        }
    }

    /// Every rung, ladder order (full → coarsest) — sweep/report order.
    pub const LADDER: [CommQuant; 5] =
        [CommQuant::F32, CommQuant::Fp16, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4];

    /// Stable position in [`CommQuant::LADDER`] — the index of the
    /// per-rung wire-byte counters (`WorkerStats::wire_bytes_by_rung`,
    /// `EngineMetrics::comm_bytes_by_rung`).
    pub fn index(self) -> usize {
        match self {
            CommQuant::F32 => 0,
            CommQuant::Fp16 => 1,
            CommQuant::Int8 => 2,
            CommQuant::Fp8 => 3,
            CommQuant::Int4 => 4,
        }
    }

    /// Canonical lowercase spelling (accepted back by
    /// [`CommQuant::parse`]) for reports and bench case names.
    pub fn label(self) -> &'static str {
        match self {
            CommQuant::F32 => "f32",
            CommQuant::Fp16 => "fp16",
            CommQuant::Int8 => "int8",
            CommQuant::Fp8 => "fp8",
            CommQuant::Int4 => "int4",
        }
    }

    /// Whether the rung re-encodes the payload below fp16 (lossy on the
    /// engine's f32 wire). The TBT-budget cost model prices every
    /// quantized rung at the int8 wire factor — conservative for
    /// fp8/int4, which move fewer bytes still.
    pub fn is_quantized(self) -> bool {
        matches!(self, CommQuant::Int8 | CommQuant::Fp8 | CommQuant::Int4)
    }
}

/// Per-phase wire-precision policy (DESIGN.md §16): which ladder rung
/// prefill collectives use, and which — usually lower — rung the fused
/// decode/verify lane uses. Decode-lane activations tolerate a coarser
/// wire than prefill logits (one token's drift vs a whole prompt's),
/// which is the ladder's whole point: resolve via
/// [`EngineConfig::precision`], never from `comm_quant` directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPolicy {
    /// Rung for prefill (and every other non-lane) collective.
    pub prefill: CommQuant,
    /// Rung for fused decode/verify-lane collectives.
    pub decode: CommQuant,
}

/// Number of segments the pre-collective GEMM is split into when compute
/// dominates (paper §3.2 "computation dominates": multiple kernel launches
/// so compute reclaims the SMs the moment comm ends).
pub const DEFAULT_GEMM_SEGMENTS: usize = 4;

/// Full engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Overlap strategy (paper Fig 1 a–d).
    pub strategy: Strategy,
    /// ISO intra-sequence split policy.
    pub split: SplitPolicy,
    /// Wire format of the ring collectives.
    pub comm_quant: CommQuant,
    /// Segments for the computation-dominates mitigation (1 = off).
    pub gemm_segments: usize,
    /// Row-segments each engine collective is streamed in (1 = one
    /// monolithic message per ring hop). The engine-side twin of the
    /// simulator's `Coster::ar_s(t, segments)` knob: higher values let
    /// the ring overlap transfer with reduction and ack partial results
    /// early, at the cost of more per-message latency (α).
    pub comm_segments: usize,
    /// Tensor-parallel degree for the real CPU engine. With pipeline
    /// stages this is the TP width *per stage*; the engine spawns
    /// `pp_stages × tp` worker pairs in total.
    pub tp: usize,
    /// Pipeline-parallel stage count (DESIGN.md §11). `1` = the classic
    /// single-stage TP engine. With `pp_stages > 1` the model's layers
    /// are partitioned into contiguous stage groups (balanced via
    /// `seg_range`), each stage internally tensor-parallel over its own
    /// ring, stages connected by bit-exact point-to-point activation
    /// handoffs; ISO's sequence chunks double as pipeline micro-batches.
    pub pp_stages: usize,
    /// Max chunk length the engine schedules (must exist in artifacts).
    pub max_chunk: usize,
    /// Max concurrent sequences in a batch.
    pub max_batch: usize,
    /// Width cap of the fused decode lane in a mixed iteration (≥ 1):
    /// up to this many live sequences decode one token each per
    /// iteration, sharing one B-row all-reduce per layer-stage.
    pub decode_batch: usize,
    /// Iteration-level mixed scheduling in `serve_trace` (DESIGN.md §9):
    /// each iteration composes the head-of-line prefill's ISO chunks with
    /// the fused decode lane. `false` = legacy per-request loop (prefill
    /// then round-robin single-token decodes) for A/B comparison.
    pub mixed_iterations: bool,
    /// Run the decode lane's MLP as one B-row GEMM when that width is
    /// compiled. Escape hatch: disable if a backend's B-row kernel is not
    /// bit-stable against per-row execution (lane *collectives* stay
    /// fused either way).
    pub lane_gemm: bool,
    /// Fused post-collective epilogue (DESIGN.md §12): collectives carry
    /// their residual tensor to the comm thread, which applies each
    /// reduced row-segment into it the moment the segment finalizes —
    /// TokenWeave-style — so the residual-add overlaps the collective's
    /// in-flight tail instead of running serially after it. Bit-exact to
    /// the unfused path (same adds, same order per element; pinned by
    /// `rust/tests/fused_epilogue.rs`). `false` = legacy per-segment acks
    /// applied on the compute thread, kept for A/B comparison.
    pub fused_epilogue: bool,
    /// Ladder-residual reordering (DESIGN.md §12, **numerics-changing**,
    /// opt-in): in the per-sequence blocking layer loops (serial-strategy
    /// prefill and legacy per-sequence decode) the MLP reads the
    /// *pre-attention* residual so both block collectives are in flight
    /// while it computes, and the two reduced partials fold in
    /// back-to-back. Changes activations (the model was not trained with
    /// this dataflow), so it is excluded from every bit-exact pin and off
    /// by default. The fused decode/verify lanes and the ISO/mixed
    /// schedules ignore it — the lanes so iteration composition never
    /// changes a sequence's math, the ISO interleave because it already
    /// fills those windows.
    pub ladder_residual: bool,
    /// Speculative decoding (DESIGN.md §10): draft tokens verified per
    /// lane sequence per iteration. `0` = off (the one-token decode
    /// lane); `k > 0` widens each lane entry into a `k + 1`-row verify
    /// window whose collectives stay fused, so a decode iteration can
    /// advance a sequence by up to `k + 1` tokens. Greedy verification
    /// keeps emitted tokens identical to `spec_k = 0`.
    pub spec_k: usize,
    /// N-gram order of the built-in self-draft proposer
    /// (`batch::NGramProposer`); only read when `spec_k > 0`.
    pub spec_ngram: usize,
    /// Decode steps to run per request after prefill (0 = prefill only).
    pub decode_steps: usize,
    /// Artifact directory for the real engine.
    pub artifacts_dir: String,
    /// Emulated wire bandwidth for the ring (MB/s). `None` = full memory
    /// speed. Throttling reproduces the paper's compute:comm ratios on the
    /// CPU testbed (DESIGN.md §2); the int8 wire then genuinely shrinks
    /// the transfer time, like the 4090's fp16→int8 compression.
    pub link_mbps: Option<f64>,
    /// Emulated per-hop latency (µs) when `link_mbps` is set.
    pub link_alpha_us: f64,
    /// Seeded deterministic fault plan (`fault::FaultPlan` grammar, e.g.
    /// `"kill:rank=1:iter=3"`), `None` = fault-free. Parsed eagerly so a
    /// typo fails at startup, not mid-serve (DESIGN.md §14).
    pub fault_plan: Option<String>,
    /// Detection-deadline slack: the leader waits `fault_slack ×` the
    /// observed per-iteration EMA before declaring a rank dead. Large by
    /// default so scheduler jitter on a loaded CI box never trips a
    /// false positive (false positives are safe — recovery preserves
    /// bit-identity — just slow).
    pub fault_slack: f64,
    /// Floor (ms) under the deadline EMA, covering cold starts and
    /// compilation pauses before the EMA has samples.
    pub deadline_floor_ms: f64,
    /// Mesh respawns the engine will attempt before giving up and
    /// surfacing the fault to the caller.
    pub max_recoveries: usize,
    /// Per-iteration decode-TBT budget (ms) enforced by bounding how
    /// many prefill chunks the mixed planner admits per iteration
    /// (DESIGN.md §15). `0.0` disables the bound: whole prompts prefill
    /// in one iteration, exactly the pre-overload behavior.
    pub tbt_budget_ms: f64,
    /// Paged-KV high-water mark as a fraction of the pool in `(0, 1]`.
    /// When used blocks exceed it, the engine preempts the youngest
    /// prefilled sequence to free pages. `1.0` disables preemption
    /// (usage can never exceed the whole pool).
    pub kv_high_water: f64,
    /// Admission queue bound; requests beyond it are rejected with
    /// `EngineError::Overloaded` instead of queueing without limit.
    /// `0` = unbounded (pre-overload behavior).
    pub queue_bound: usize,
    /// Preemptions allowed per sequence before it becomes unevictable;
    /// the anti-livelock cap of DESIGN.md §15.
    pub max_preemptions: usize,
    /// TTFT deadline (ms): queued requests that have already waited
    /// longer are shed at admission time rather than served late.
    /// `0.0` disables shedding.
    pub ttft_deadline_ms: f64,
    /// Wire-precision override for *all* collectives (`--wire-precision`,
    /// DESIGN.md §16). `None` = use `comm_quant`, byte-identical to the
    /// pre-ladder engine.
    pub wire_precision: Option<CommQuant>,
    /// Wire-precision override for the fused decode/verify lane only
    /// (`--decode-wire-precision`). `None` = same rung as prefill.
    pub decode_wire_precision: Option<CommQuant>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            strategy: Strategy::Iso,
            split: SplitPolicy::AttnBalanced,
            comm_quant: CommQuant::F32,
            gemm_segments: DEFAULT_GEMM_SEGMENTS,
            comm_segments: 1,
            tp: 2,
            pp_stages: 1,
            max_chunk: 64,
            max_batch: 8,
            decode_batch: 8,
            mixed_iterations: true,
            lane_gemm: true,
            fused_epilogue: true,
            ladder_residual: false,
            spec_k: 0,
            spec_ngram: 2,
            decode_steps: 0,
            artifacts_dir: "artifacts".into(),
            link_mbps: None,
            link_alpha_us: 50.0,
            fault_plan: None,
            fault_slack: 32.0,
            deadline_floor_ms: 250.0,
            max_recoveries: 4,
            tbt_budget_ms: 0.0,
            kv_high_water: 1.0,
            queue_bound: 0,
            max_preemptions: 2,
            ttft_deadline_ms: 0.0,
            wire_precision: None,
            decode_wire_precision: None,
        }
    }
}

impl EngineConfig {
    /// Resolve the per-phase precision policy: `wire_precision` (else
    /// `comm_quant`) for prefill, `decode_wire_precision` (else the
    /// prefill rung) for the fused decode/verify lane.
    pub fn precision(&self) -> PrecisionPolicy {
        let prefill = self.wire_precision.unwrap_or(self.comm_quant);
        let decode = self.decode_wire_precision.unwrap_or(prefill);
        PrecisionPolicy { prefill, decode }
    }
}

/// A fully-specified simulator experiment (one Table-1 cell).
#[derive(Clone, Debug)]
pub struct SimExperiment {
    /// Modeled node (device × cards × interconnect).
    pub node: NodeProfile,
    /// Modeled transformer geometry.
    pub model: ModelSpec,
    /// Prefill prompt length.
    pub prompt_len: usize,
    /// Overlap strategy under test.
    pub strategy: Strategy,
    /// ISO split policy.
    pub split: SplitPolicy,
    /// Whether collectives quantize to int8 on the wire.
    pub int8_wire: bool,
    /// Launches the pre-collective GEMMs are segmented into.
    pub gemm_segments: usize,
}

impl SimExperiment {
    /// An experiment with the node's default wire format and balanced split.
    pub fn new(node: NodeProfile, model: ModelSpec, prompt_len: usize, strategy: Strategy) -> Self {
        let int8_wire = node.int8_wire_default;
        SimExperiment {
            node,
            model,
            prompt_len,
            strategy,
            split: SplitPolicy::AttnBalanced,
            int8_wire,
            gemm_segments: DEFAULT_GEMM_SEGMENTS,
        }
    }
}

/// Parse the line-based config format:
/// ```text
/// [engine]
/// strategy = iso
/// tp = 4
/// ```
pub fn parse_config_file(path: &Path) -> Result<BTreeMap<String, String>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    parse_config_str(&text)
}

/// Keys are returned as `section.key` (or bare `key` before any section).
pub fn parse_config_str(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

/// The accepted boolean spellings for config keys and CLI flags alike.
pub fn parse_bool(v: &str, key: &str) -> Result<bool, String> {
    match v {
        "true" | "on" | "1" => Ok(true),
        "false" | "off" | "0" => Ok(false),
        _ => Err(format!("bad {key} {v:?}")),
    }
}

impl EngineConfig {
    /// Build from parsed `section.key` pairs; unknown keys are errors so
    /// typos don't silently fall back to defaults.
    pub fn from_map(map: &BTreeMap<String, String>) -> Result<Self, String> {
        let mut cfg = EngineConfig::default();
        for (k, v) in map {
            match k.as_str() {
                "engine.strategy" => {
                    cfg.strategy =
                        Strategy::parse(v).ok_or_else(|| format!("bad strategy {v:?}"))?
                }
                "engine.split" => {
                    cfg.split = SplitPolicy::parse(v).ok_or_else(|| format!("bad split {v:?}"))?
                }
                "engine.comm_quant" => {
                    cfg.comm_quant =
                        CommQuant::parse(v).ok_or_else(|| format!("bad comm_quant {v:?}"))?
                }
                "engine.gemm_segments" => {
                    cfg.gemm_segments = v.parse().map_err(|_| format!("bad gemm_segments {v:?}"))?
                }
                "engine.comm_segments" => {
                    cfg.comm_segments = v.parse().map_err(|_| format!("bad comm_segments {v:?}"))?
                }
                "engine.tp" => cfg.tp = v.parse().map_err(|_| format!("bad tp {v:?}"))?,
                "engine.pp_stages" => {
                    cfg.pp_stages = v.parse().map_err(|_| format!("bad pp_stages {v:?}"))?
                }
                "engine.max_chunk" => {
                    cfg.max_chunk = v.parse().map_err(|_| format!("bad max_chunk {v:?}"))?
                }
                "engine.max_batch" => {
                    cfg.max_batch = v.parse().map_err(|_| format!("bad max_batch {v:?}"))?
                }
                "engine.decode_batch" => {
                    cfg.decode_batch =
                        v.parse().map_err(|_| format!("bad decode_batch {v:?}"))?
                }
                "engine.mixed_iterations" => {
                    cfg.mixed_iterations = parse_bool(v, "mixed_iterations")?
                }
                "engine.lane_gemm" => cfg.lane_gemm = parse_bool(v, "lane_gemm")?,
                "engine.fused_epilogue" => {
                    cfg.fused_epilogue = parse_bool(v, "fused_epilogue")?
                }
                "engine.ladder_residual" => {
                    cfg.ladder_residual = parse_bool(v, "ladder_residual")?
                }
                "engine.spec_k" => {
                    cfg.spec_k = v.parse().map_err(|_| format!("bad spec_k {v:?}"))?
                }
                "engine.spec_ngram" => {
                    cfg.spec_ngram = v.parse().map_err(|_| format!("bad spec_ngram {v:?}"))?
                }
                "engine.decode_steps" => {
                    cfg.decode_steps = v.parse().map_err(|_| format!("bad decode_steps {v:?}"))?
                }
                "engine.artifacts_dir" => cfg.artifacts_dir = v.clone(),
                "engine.link_mbps" => {
                    cfg.link_mbps =
                        Some(v.parse().map_err(|_| format!("bad link_mbps {v:?}"))?)
                }
                "engine.link_alpha_us" => {
                    cfg.link_alpha_us = v.parse().map_err(|_| format!("bad link_alpha_us {v:?}"))?
                }
                "engine.fault_plan" => cfg.fault_plan = Some(v.clone()),
                "engine.fault_slack" => {
                    cfg.fault_slack = v.parse().map_err(|_| format!("bad fault_slack {v:?}"))?
                }
                "engine.deadline_floor_ms" => {
                    cfg.deadline_floor_ms =
                        v.parse().map_err(|_| format!("bad deadline_floor_ms {v:?}"))?
                }
                "engine.max_recoveries" => {
                    cfg.max_recoveries =
                        v.parse().map_err(|_| format!("bad max_recoveries {v:?}"))?
                }
                "engine.tbt_budget_ms" => {
                    cfg.tbt_budget_ms =
                        v.parse().map_err(|_| format!("bad tbt_budget_ms {v:?}"))?
                }
                "engine.kv_high_water" => {
                    cfg.kv_high_water =
                        v.parse().map_err(|_| format!("bad kv_high_water {v:?}"))?
                }
                "engine.queue_bound" => {
                    cfg.queue_bound = v.parse().map_err(|_| format!("bad queue_bound {v:?}"))?
                }
                "engine.max_preemptions" => {
                    cfg.max_preemptions =
                        v.parse().map_err(|_| format!("bad max_preemptions {v:?}"))?
                }
                "engine.ttft_deadline_ms" => {
                    cfg.ttft_deadline_ms =
                        v.parse().map_err(|_| format!("bad ttft_deadline_ms {v:?}"))?
                }
                "engine.wire_precision" => {
                    cfg.wire_precision = Some(
                        CommQuant::parse(v).ok_or_else(|| format!("bad wire_precision {v:?}"))?,
                    )
                }
                "engine.decode_wire_precision" => {
                    cfg.decode_wire_precision = Some(
                        CommQuant::parse(v)
                            .ok_or_else(|| format!("bad decode_wire_precision {v:?}"))?,
                    )
                }
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        if cfg.gemm_segments == 0 {
            return Err("gemm_segments must be >= 1".into());
        }
        if cfg.comm_segments == 0 {
            return Err("comm_segments must be >= 1".into());
        }
        if cfg.decode_batch == 0 {
            return Err("decode_batch must be >= 1".into());
        }
        if cfg.spec_ngram == 0 {
            return Err("spec_ngram must be >= 1".into());
        }
        if cfg.pp_stages == 0 {
            return Err("pp_stages must be >= 1".into());
        }
        if cfg.fault_slack < 1.0 {
            return Err("fault_slack must be >= 1".into());
        }
        if cfg.tbt_budget_ms < 0.0 {
            return Err("tbt_budget_ms must be >= 0".into());
        }
        if !(cfg.kv_high_water > 0.0 && cfg.kv_high_water <= 1.0) {
            return Err("kv_high_water must be in (0, 1]".into());
        }
        if cfg.ttft_deadline_ms < 0.0 {
            return Err("ttft_deadline_ms must be >= 0".into());
        }
        if let Some(plan) = &cfg.fault_plan {
            // Parse eagerly so a typo'd plan fails at startup.
            crate::fault::FaultPlan::parse(plan).map_err(|e| format!("bad fault_plan: {e}"))?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::parse(&s.to_string()), Some(s));
        }
        assert_eq!(Strategy::parse("GEMM-OVERLAP"), Some(Strategy::GemmOverlap));
        assert!(Strategy::parse("magic").is_none());
    }

    #[test]
    fn split_policy_parse() {
        assert_eq!(SplitPolicy::parse("even"), Some(SplitPolicy::Even));
        assert_eq!(SplitPolicy::parse("ratio:0.6"), Some(SplitPolicy::Ratio(0.6)));
        assert_eq!(SplitPolicy::parse("balanced"), Some(SplitPolicy::AttnBalanced));
        assert_eq!(SplitPolicy::parse("adaptive"), Some(SplitPolicy::AdaptiveAttnMlp));
        assert!(SplitPolicy::parse("ratio:1.5").is_none());
        assert!(SplitPolicy::parse("ratio:abc").is_none());
    }

    #[test]
    fn config_file_parsing() {
        let text = r#"
            # a comment
            [engine]
            strategy = iso       # trailing comment
            split = ratio:0.6
            tp = 4
            comm_quant = int8
            comm_segments = 4
            decode_batch = 4
            mixed_iterations = false
        "#;
        let map = parse_config_str(text).unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.strategy, Strategy::Iso);
        assert_eq!(cfg.split, SplitPolicy::Ratio(0.6));
        assert_eq!(cfg.tp, 4);
        assert_eq!(cfg.comm_quant, CommQuant::Int8);
        assert_eq!(cfg.comm_segments, 4);
        assert_eq!(cfg.decode_batch, 4);
        assert!(!cfg.mixed_iterations);
    }

    #[test]
    fn mixed_batching_defaults_and_validation() {
        let cfg = EngineConfig::default();
        assert!(cfg.mixed_iterations);
        assert!(cfg.lane_gemm);
        assert_eq!(cfg.decode_batch, 8);
        let map = parse_config_str("[engine]\ndecode_batch = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\nmixed_iterations = maybe").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\nlane_gemm = off").unwrap();
        assert!(!EngineConfig::from_map(&map).unwrap().lane_gemm);
    }

    #[test]
    fn fused_epilogue_and_ladder_knobs() {
        let cfg = EngineConfig::default();
        assert!(cfg.fused_epilogue, "fused epilogue is the default path");
        assert!(!cfg.ladder_residual, "numerics-changing mode must be opt-in");
        let map = parse_config_str(
            "[engine]\nfused_epilogue = off\nladder_residual = on",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert!(!cfg.fused_epilogue);
        assert!(cfg.ladder_residual);
        let bad = parse_config_str("[engine]\nfused_epilogue = maybe").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nladder_residual = 2").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn spec_decode_knobs_parse_and_validate() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.spec_k, 0, "speculation must be opt-in");
        assert_eq!(cfg.spec_ngram, 2);
        let map = parse_config_str("[engine]\nspec_k = 4\nspec_ngram = 3").unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.spec_k, 4);
        assert_eq!(cfg.spec_ngram, 3);
        let bad = parse_config_str("[engine]\nspec_ngram = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nspec_k = many").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn pp_stages_parses_and_validates() {
        assert_eq!(EngineConfig::default().pp_stages, 1, "PP must be opt-in");
        let map = parse_config_str("[engine]\npp_stages = 2\ntp = 2").unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!((cfg.pp_stages, cfg.tp), (2, 2));
        let bad = parse_config_str("[engine]\npp_stages = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\npp_stages = two").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn comm_quant_ladder_parses() {
        assert_eq!(CommQuant::parse("fp8"), Some(CommQuant::Fp8));
        assert_eq!(CommQuant::parse("E5M2"), Some(CommQuant::Fp8));
        assert_eq!(CommQuant::parse("int4"), Some(CommQuant::Int4));
        assert_eq!(CommQuant::parse("i4"), Some(CommQuant::Int4));
        assert!(CommQuant::parse("int2").is_none());
    }

    #[test]
    fn precision_policy_defaults_to_comm_quant() {
        // Acceptance pin: with neither override set, the policy is
        // `comm_quant` on both phases — byte-identical pre-ladder
        // behavior, including the existing int8 opt-in.
        let mut cfg = EngineConfig::default();
        let p = cfg.precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::F32, CommQuant::F32));
        cfg.comm_quant = CommQuant::Int8;
        let p = cfg.precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Int8, CommQuant::Int8));
    }

    #[test]
    fn precision_policy_overrides_cascade() {
        let map = parse_config_str("[engine]\nwire_precision = fp8").unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Fp8, CommQuant::Fp8));

        // decode override alone lowers only the lane rung.
        let map = parse_config_str("[engine]\ndecode_wire_precision = int4").unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::F32, CommQuant::Int4));

        let map = parse_config_str(
            "[engine]\ncomm_quant = int8\nwire_precision = fp8\n\
             decode_wire_precision = int4",
        )
        .unwrap();
        let p = EngineConfig::from_map(&map).unwrap().precision();
        assert_eq!((p.prefill, p.decode), (CommQuant::Fp8, CommQuant::Int4));

        let bad = parse_config_str("[engine]\nwire_precision = int2").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn comm_quant_wire_bytes_hand_arithmetic() {
        // The bytes columns of BENCH_PRECISION.json trace to this table.
        let (r, c) = (8, 17); // odd cols exercise the int4 ceil
        assert_eq!(CommQuant::F32.wire_bytes(r, c), 8 * 17 * 4);
        assert_eq!(CommQuant::Fp16.wire_bytes(r, c), 8 * 17 * 4); // modeled
        assert_eq!(CommQuant::Int8.wire_bytes(r, c), 8 * 4 + 8 * 17);
        assert_eq!(CommQuant::Fp8.wire_bytes(r, c), 8 * 17);
        assert_eq!(CommQuant::Int4.wire_bytes(r, c), 8 * 4 + 8 * 9);
    }

    #[test]
    fn unknown_key_rejected() {
        let map = parse_config_str("[engine]\nstrtegy = iso").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
    }

    #[test]
    fn bad_value_rejected() {
        let map = parse_config_str("[engine]\ntp = four").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\ngemm_segments = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
        let map = parse_config_str("[engine]\ncomm_segments = 0").unwrap();
        assert!(EngineConfig::from_map(&map).is_err());
    }

    #[test]
    fn missing_equals_is_error() {
        assert!(parse_config_str("[engine]\nstrategy iso").is_err());
    }

    #[test]
    fn fault_knobs_default_off_and_parse() {
        let cfg = EngineConfig::default();
        assert!(cfg.fault_plan.is_none(), "fault injection must be opt-in");
        assert!(cfg.fault_slack >= 1.0);
        assert!(cfg.deadline_floor_ms > 0.0);
        assert!(cfg.max_recoveries >= 1);

        let map = parse_config_str(
            "[engine]\nfault_plan = kill:rank=1:iter=3\nfault_slack = 8\n\
             deadline_floor_ms = 100\nmax_recoveries = 2",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.fault_plan.as_deref(), Some("kill:rank=1:iter=3"));
        assert_eq!(cfg.fault_slack, 8.0);
        assert_eq!(cfg.deadline_floor_ms, 100.0);
        assert_eq!(cfg.max_recoveries, 2);
    }

    #[test]
    fn fault_knobs_validated() {
        // A typo'd plan fails at parse time, not mid-serve.
        let bad = parse_config_str("[engine]\nfault_plan = kill:rank=1").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nfault_slack = 0.5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn overload_knobs_default_off_and_parse() {
        // Every overload knob defaults off: an unconfigured engine
        // behaves byte-identically to the pre-overload scheduler.
        let cfg = EngineConfig::default();
        assert_eq!(cfg.tbt_budget_ms, 0.0, "prefill bounding must be opt-in");
        assert_eq!(cfg.kv_high_water, 1.0, "preemption must be opt-in");
        assert_eq!(cfg.queue_bound, 0, "backpressure must be opt-in");
        assert_eq!(cfg.ttft_deadline_ms, 0.0, "shedding must be opt-in");
        assert!(cfg.max_preemptions >= 1);

        let map = parse_config_str(
            "[engine]\ntbt_budget_ms = 50\nkv_high_water = 0.85\n\
             queue_bound = 64\nmax_preemptions = 3\nttft_deadline_ms = 500",
        )
        .unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert_eq!(cfg.tbt_budget_ms, 50.0);
        assert_eq!(cfg.kv_high_water, 0.85);
        assert_eq!(cfg.queue_bound, 64);
        assert_eq!(cfg.max_preemptions, 3);
        assert_eq!(cfg.ttft_deadline_ms, 500.0);
    }

    #[test]
    fn overload_knobs_validated() {
        let bad = parse_config_str("[engine]\nkv_high_water = 0").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nkv_high_water = 1.5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\ntbt_budget_ms = -1").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
        let bad = parse_config_str("[engine]\nttft_deadline_ms = -5").unwrap();
        assert!(EngineConfig::from_map(&bad).is_err());
    }

    #[test]
    fn experiment_inherits_node_wire_default() {
        use crate::hw::NodeProfile;
        use crate::model::ModelSpec;
        let e = SimExperiment::new(
            NodeProfile::rtx4090(4),
            ModelSpec::mha_30b(),
            4096,
            Strategy::Iso,
        );
        assert!(e.int8_wire);
        let e = SimExperiment::new(NodeProfile::a800(4), ModelSpec::gqa_70b(), 4096, Strategy::Iso);
        assert!(!e.int8_wire);
    }
}
