//! Sequence-split policies for ISO's intra-sequence micro-batches.
//!
//! Paper §3.1 splits 50/50; §6 observes that causal attention makes the
//! second half markedly heavier and proposes uneven splits (e.g. 60/40)
//! and, further, decoupling the attention split from the MLP split
//! (Fig 3). `choose_split` implements all of these against the calibrated
//! cost model so the simulator, the benches, and the real engine agree on
//! the split point.

use crate::config::SplitPolicy;
use crate::hw::NodeProfile;
use crate::model::ModelSpec;

/// Calibrated context threaded from the engine/bench config into
/// `batch::plan_prefill`, so the engine-side split point comes from the
/// same `choose_split` bisection the simulator uses instead of a
/// hardcoded ratio (the old `0.55` closed form survives only as the
/// fallback when no profile is supplied).
#[derive(Clone, Debug)]
pub struct SplitContext {
    /// Calibrated node profile.
    pub node: NodeProfile,
    /// Model geometry being served.
    pub model: ModelSpec,
}

impl SplitContext {
    /// A context from explicit parts.
    pub fn new(node: NodeProfile, model: ModelSpec) -> Self {
        SplitContext { node, model }
    }

    /// The real CPU engine's own calibrated testbed: its worker count,
    /// its (optionally throttled) ring link, and the tiny model it
    /// actually executes.
    pub fn engine(cfg: &crate::config::EngineConfig) -> Self {
        SplitContext {
            node: NodeProfile::cpu_engine(cfg.tp, cfg.link_mbps, cfg.link_alpha_us),
            model: ModelSpec::tiny_gqa(),
        }
    }
}

/// The token counts assigned to the two micro-batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Split {
    /// Tokens in chunk 0 (attention phase).
    pub t0: usize,
    /// Tokens in chunk 1.
    pub t1: usize,
    /// Tokens in the MLP micro-batches (== t0/t1 unless AdaptiveAttnMlp).
    pub mlp_t0: usize,
    /// Tokens in MLP micro-batch 1.
    pub mlp_t1: usize,
}

impl Split {
    /// Total tokens across both chunks.
    pub fn total(&self) -> usize {
        self.t0 + self.t1
    }
}

/// Per-chunk compute time (one device) of the *whole layer* — used to
/// balance the two chunks.
fn chunk_time_s(node: &NodeProfile, model: &ModelSpec, t: usize, offset: usize) -> f64 {
    if t == 0 {
        return 0.0;
    }
    let c = model.layer_chunk_cost(t, offset);
    let flops = (c.gemm_flops_attn + c.gemm_flops_mlp + c.attn_flops) / node.cards as f64;
    node.device.gemm_s(flops, t)
}

/// Attention-only per-chunk time (for the AdaptiveAttnMlp balance).
fn attn_time_s(node: &NodeProfile, model: &ModelSpec, t: usize, offset: usize) -> f64 {
    if t == 0 {
        return 0.0;
    }
    let c = model.layer_chunk_cost(t, offset);
    let flops = (c.gemm_flops_attn + c.attn_flops) / node.cards as f64;
    node.device.gemm_s(flops, t)
}

/// Pick the split point for a prompt of `t` tokens.
pub fn choose_split(
    policy: SplitPolicy,
    node: &NodeProfile,
    model: &ModelSpec,
    t: usize,
) -> Split {
    assert!(t >= 2, "cannot split a prompt of {t} tokens");
    let t0 = match policy {
        SplitPolicy::Even => t / 2,
        SplitPolicy::Ratio(r) => ((t as f64 * r).round() as usize).clamp(1, t - 1),
        SplitPolicy::AttnBalanced | SplitPolicy::AdaptiveAttnMlp => {
            balance(t, |t0| {
                let a = chunk_time_s(node, model, t0, 0);
                let b = chunk_time_s(node, model, t - t0, t0);
                a - b
            })
        }
    };
    let (mlp_t0, mlp_t1) = match policy {
        // Fig 3: MLP cost is position-free, so its micro-batches split
        // evenly regardless of the attention split.
        SplitPolicy::AdaptiveAttnMlp => (t / 2, t - t / 2),
        _ => (t0, t - t0),
    };
    Split { t0, t1: t - t0, mlp_t0, mlp_t1 }
}

/// Find t0 in [1, t-1] where `f(t0)` crosses zero (f is monotone
/// increasing in t0 for our cost shapes); returns the closest integer.
fn balance(t: usize, f: impl Fn(usize) -> f64) -> usize {
    let (mut lo, mut hi) = (1usize, t - 1);
    if f(lo) >= 0.0 {
        return lo;
    }
    if f(hi) <= 0.0 {
        return hi;
    }
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if f(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Pick whichever side is closer to balanced.
    if f(lo).abs() <= f(hi).abs() {
        lo
    } else {
        hi
    }
}

/// Predicted imbalance |time0 - time1| / max for diagnostics and the Fig-3
/// bench.
pub fn imbalance(node: &NodeProfile, model: &ModelSpec, s: &Split) -> f64 {
    let a = chunk_time_s(node, model, s.t0, 0);
    let b = chunk_time_s(node, model, s.t1, s.t0);
    (a - b).abs() / a.max(b)
}

/// Attention-phase imbalance (drives Fig 3's motivation).
pub fn attn_imbalance(node: &NodeProfile, model: &ModelSpec, s: &Split) -> f64 {
    let a = attn_time_s(node, model, s.t0, 0);
    let b = attn_time_s(node, model, s.t1, s.t0);
    (a - b).abs() / a.max(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prop;

    fn setup() -> (NodeProfile, ModelSpec) {
        (NodeProfile::a800(4), ModelSpec::gqa_70b())
    }

    #[test]
    fn even_split_halves() {
        let (n, m) = setup();
        let s = choose_split(SplitPolicy::Even, &n, &m, 4096);
        assert_eq!((s.t0, s.t1), (2048, 2048));
        assert_eq!((s.mlp_t0, s.mlp_t1), (2048, 2048));
    }

    #[test]
    fn ratio_split() {
        let (n, m) = setup();
        let s = choose_split(SplitPolicy::Ratio(0.6), &n, &m, 1000);
        assert_eq!(s.t0, 600);
        assert_eq!(s.t1, 400);
    }

    #[test]
    fn balanced_split_gives_first_chunk_more_tokens() {
        // Causal attention: chunk 1 attends over chunk 0's KV too, so the
        // balanced point puts MORE tokens in chunk 0 (paper §6's 60/40).
        let (n, m) = setup();
        for t in [2048usize, 8192, 32768] {
            let s = choose_split(SplitPolicy::AttnBalanced, &n, &m, t);
            assert!(s.t0 > s.t1, "t={t}: t0={} t1={}", s.t0, s.t1);
            assert!(s.t0 < (t as f64 * 0.75) as usize, "t={t}: t0={}", s.t0);
        }
    }

    #[test]
    fn balanced_split_reduces_imbalance_vs_even() {
        let (n, m) = setup();
        for t in [4096usize, 16384] {
            let even = choose_split(SplitPolicy::Even, &n, &m, t);
            let bal = choose_split(SplitPolicy::AttnBalanced, &n, &m, t);
            assert!(
                imbalance(&n, &m, &bal) < imbalance(&n, &m, &even),
                "t={t}: bal {} !< even {}",
                imbalance(&n, &m, &bal),
                imbalance(&n, &m, &even)
            );
            assert!(imbalance(&n, &m, &bal) < 0.03, "t={t}");
        }
    }

    #[test]
    fn adaptive_attn_mlp_splits_mlp_evenly() {
        let (n, m) = setup();
        let s = choose_split(SplitPolicy::AdaptiveAttnMlp, &n, &m, 8192);
        assert!(s.t0 > s.t1); // attention still balanced
        assert_eq!(s.mlp_t0, 4096);
        assert_eq!(s.mlp_t1, 4096);
        assert_eq!(s.t0 + s.t1, 8192);
    }

    #[test]
    fn longer_prompts_push_balance_toward_60_40() {
        // As the quadratic attention term grows, the balanced first chunk
        // grows past 50% toward the paper's illustrative 60%.
        let (n, m) = setup();
        let frac = |t: usize| {
            let s = choose_split(SplitPolicy::AttnBalanced, &n, &m, t);
            s.t0 as f64 / t as f64
        };
        assert!(frac(65536) > frac(1024));
        assert!((0.5..0.75).contains(&frac(65536)));
    }

    #[test]
    fn prop_split_conserves_tokens() {
        let (n, m) = setup();
        Prop::new(23).cases(128).run("split conserves tokens", |rng| {
            let t = rng.range(2, 65536);
            for policy in [
                SplitPolicy::Even,
                SplitPolicy::Ratio(rng.f32_range(0.1, 0.9) as f64),
                SplitPolicy::AttnBalanced,
                SplitPolicy::AdaptiveAttnMlp,
            ] {
                let s = choose_split(policy, &n, &m, t);
                if s.t0 + s.t1 != t || s.mlp_t0 + s.mlp_t1 != t {
                    return Err(format!("{policy:?} t={t}: {s:?}"));
                }
                if s.t0 == 0 || s.t1 == 0 {
                    return Err(format!("{policy:?} t={t}: empty chunk {s:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn engine_split_context_uses_cpu_profile() {
        let cfg = crate::config::EngineConfig::default();
        let ctx = SplitContext::engine(&cfg);
        assert_eq!(ctx.node.device.name, "cpu-engine");
        assert_eq!(ctx.node.cards, cfg.tp);
        assert_eq!(ctx.model.name, "tiny-gqa");
        // The balanced bisection is solvable against it.
        let s = choose_split(SplitPolicy::AttnBalanced, &ctx.node, &ctx.model, 128);
        assert_eq!(s.total(), 128);
        assert!(s.t0 >= 1 && s.t1 >= 1);
    }

    #[test]
    fn attn_imbalance_shrinks_under_balanced_policy() {
        let (n, m) = setup();
        let even = choose_split(SplitPolicy::Even, &n, &m, 16384);
        let adaptive = choose_split(SplitPolicy::AdaptiveAttnMlp, &n, &m, 16384);
        assert!(attn_imbalance(&n, &m, &adaptive) < attn_imbalance(&n, &m, &even));
    }
}
