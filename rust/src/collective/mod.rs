//! Real ring all-reduce across tensor-parallel worker threads.
//!
//! This is the communication that ISO overlaps. Each TP rank is a thread;
//! ranks are connected in a ring of mpsc channels (the CPU stand-in for
//! NCCL's NVLink/PCIe ring — same algorithm, same step structure:
//! reduce-scatter then all-gather, 2(R−1) steps moving 1/R of the payload
//! each).
//!
//! Wire formats (paper §3.2 "communication dominates"): `F32` sends raw
//! activations; `Int8` quantizes each hop's segment with per-row scales
//! (`quant::quantize_rows_into`), cutting wire bytes ~4× at a bounded,
//! tested accuracy cost — the CPU analogue of the paper's fp16→int8
//! compression. PR 8 extends the ladder downward (DESIGN.md §16): `Fp8`
//! moves software-emulated e5m2 bytes (elementwise, no scale vector) and
//! `I4` packs two's-complement nibbles with per-row scales. Every rung's
//! encoding is row-local, so segmentation stays bit-exact and the fused
//! B-row lane collective stays bit-identical to B single-row calls.
//!
//! Segmented streaming (DESIGN.md §4): `allreduce_seg` splits every hop's
//! chunk into `segments` sub-messages sent double-buffered — one message
//! in flight while the previous one is reduced — so the wire time of
//! sub-message `k+1` overlaps the dequantize/accumulate of sub-message
//! `k`. Because the ring's chunk↔rank mapping (and therefore the
//! per-element accumulation order) is untouched, the segmented result is
//! **bit-identical** to the unsegmented path for every wire format. All
//! wire buffers come from a per-rank [`BufferPool`]; received buffers are
//! recycled into the receiver's pool, so buffers circulate around the
//! ring and the steady state allocates nothing.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::config::CommQuant;
use crate::fault::EngineError;
use crate::quant::quantize_rows_into;

/// One hop's payload.
enum Wire {
    F32(Vec<f32>),
    I8 { rows: usize, cols: usize, scales: Vec<f32>, data: Vec<i8> },
    Fp8 { rows: usize, cols: usize, data: Vec<u8> },
    I4 { rows: usize, cols: usize, scales: Vec<f32>, data: Vec<u8> },
}

impl Wire {
    /// Wire size: every variant counts its scale vector (4 bytes per
    /// scale) plus its packed payload — int4 is `ceil(cols/2)` bytes per
    /// row, already reflected in `data.len()`. Pinned against hand
    /// arithmetic by `wire_bytes_count_scales_and_packing` below and the
    /// matching `config::CommQuant::wire_bytes` table, so the engine's
    /// `comm_bytes` counters and the BENCH_PRECISION.json bytes columns
    /// agree.
    fn bytes(&self) -> usize {
        match self {
            Wire::F32(v) => v.len() * 4,
            Wire::I8 { scales, data, .. } => scales.len() * 4 + data.len(),
            Wire::Fp8 { data, .. } => data.len(),
            Wire::I4 { scales, data, .. } => scales.len() * 4 + data.len(),
        }
    }
}

/// A wire message: payload plus its modeled arrival deadline.
struct Packet {
    /// When the bytes finish "arriving" under [`Throttle`]; `None` when
    /// the link runs at memory speed.
    arrive_at: Option<Instant>,
    wire: Wire,
    /// Fault injection: a modeled CRC failure. The receiver surfaces
    /// [`EngineError::WireCorrupt`] instead of applying the payload.
    poisoned: bool,
}

/// Reusable per-rank wire buffers (DESIGN.md §4). Senders draw from the
/// pool; receivers recycle arrived buffers back into *their* pool, so in
/// steady state buffers circulate the ring and no hop allocates.
#[derive(Debug, Default)]
pub struct BufferPool {
    f32_free: Vec<Vec<f32>>,
    i8_free: Vec<Vec<i8>>,
    u8_free: Vec<Vec<u8>>,
    /// Buffers created because the pool was empty.
    pub allocs: u64,
    /// Buffers served from the free list.
    pub reuses: u64,
}

impl BufferPool {
    /// Free-list cap; beyond this, returned buffers are dropped.
    const MAX_FREE: usize = 64;

    /// An empty f32 buffer, pooled when available.
    pub fn take_f32(&mut self) -> Vec<f32> {
        match self.f32_free.pop() {
            Some(v) => {
                self.reuses += 1;
                v
            }
            None => {
                self.allocs += 1;
                Vec::new()
            }
        }
    }

    /// Return an f32 buffer to the pool (dropped past the cap).
    pub fn put_f32(&mut self, mut v: Vec<f32>) {
        if self.f32_free.len() < Self::MAX_FREE {
            v.clear();
            self.f32_free.push(v);
        }
    }

    /// An empty i8 buffer, pooled when available.
    pub fn take_i8(&mut self) -> Vec<i8> {
        match self.i8_free.pop() {
            Some(v) => {
                self.reuses += 1;
                v
            }
            None => {
                self.allocs += 1;
                Vec::new()
            }
        }
    }

    /// Return an i8 buffer to the pool (dropped past the cap).
    pub fn put_i8(&mut self, mut v: Vec<i8>) {
        if self.i8_free.len() < Self::MAX_FREE {
            v.clear();
            self.i8_free.push(v);
        }
    }

    /// An empty u8 buffer (fp8 codes / packed int4 nibbles), pooled when
    /// available.
    pub fn take_u8(&mut self) -> Vec<u8> {
        match self.u8_free.pop() {
            Some(v) => {
                self.reuses += 1;
                v
            }
            None => {
                self.allocs += 1;
                Vec::new()
            }
        }
    }

    /// Return a u8 buffer to the pool (dropped past the cap).
    pub fn put_u8(&mut self, mut v: Vec<u8>) {
        if self.u8_free.len() < Self::MAX_FREE {
            v.clear();
            self.u8_free.push(v);
        }
    }
}

/// Emulated link speed for the ring (DESIGN.md §2: the CPU testbed's
/// shared-memory channels are far faster than PCIe/NVLink relative to its
/// compute, so engine experiments can throttle each hop to a calibrated
/// `alpha + bytes/bandwidth` — the same α/β model the simulator uses.
/// Quantized wire formats then genuinely shrink the transfer time, exactly
/// like the paper's fp16→int8 compression on the 4090).
///
/// The link is modeled as an asynchronous DMA engine: the **sender**
/// stamps each message with an arrival deadline (`max(link free, now) +
/// α + bytes/B`) and returns immediately; the **receiver** sleeps until
/// the deadline before touching the payload. CPU work on either side
/// therefore genuinely overlaps wire time, which is what makes segmented
/// streaming hide the reduction cost (DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Throttle {
    /// Per-message latency (seconds).
    pub alpha_s: f64,
    /// Wire bandwidth in bytes/second.
    pub bytes_per_s: f64,
}

impl Throttle {
    /// Time for `bytes` to cross the link.
    pub fn wire_s(&self, bytes: usize) -> f64 {
        self.alpha_s + bytes as f64 / self.bytes_per_s
    }
}

/// A rank's handle into the ring; moved into its worker thread.
pub struct RingHandle {
    /// This rank's position in the ring.
    pub rank: usize,
    /// Ring size (TP degree).
    pub n: usize,
    tx_next: Sender<Packet>,
    rx_prev: Receiver<Packet>,
    /// Total wire bytes this rank has sent.
    pub sent_bytes: u64,
    /// Total wire messages this rank has sent.
    pub sent_msgs: u64,
    /// Optional emulated link speed.
    pub throttle: Option<Throttle>,
    /// When this rank's outgoing link frees up (throttled mode).
    link_busy: Option<Instant>,
    /// Reusable wire buffers.
    pool: BufferPool,
    /// Fault injection: flag the next outgoing segment corrupt.
    poison_next: bool,
}

/// Build a ring of `n` handles (index = rank).
pub fn ring(n: usize) -> Vec<RingHandle> {
    assert!(n >= 1);
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // rank r sends to (r+1)%n, so its tx is txs[(r+1)%n]'s producing end;
    // rotate the tx list left by one relative to rx.
    let mut handles = Vec::with_capacity(n);
    let mut txs_rot: Vec<Option<Sender<Packet>>> = txs.into_iter().map(Some).collect();
    for (r, rx) in rxs.into_iter().enumerate() {
        let tx = txs_rot[(r + 1) % n].take().expect("tx taken twice");
        handles.push(RingHandle {
            rank: r,
            n,
            tx_next: tx,
            rx_prev: rx,
            sent_bytes: 0,
            sent_msgs: 0,
            throttle: None,
            link_busy: None,
            pool: BufferPool::default(),
            poison_next: false,
        });
    }
    handles
}

/// Row-range of segment `i` when `rows` are split into `n` contiguous
/// segments: the first `rows % n` segments get one extra row, so the
/// ranges partition `[0, rows)` exactly (no gap, no overlap) for any
/// `rows` and `n >= 1`, including `rows < n` (trailing segments empty).
pub fn seg_range(rows: usize, n: usize, i: usize) -> (usize, usize) {
    let base = rows / n;
    let extra = rows % n;
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    (start, start + len)
}

impl RingHandle {
    /// Fault injection: flag this rank's next outgoing ring segment as
    /// corrupt (a modeled CRC failure). The downstream peer's receive
    /// surfaces [`EngineError::WireCorrupt`] on the supervised (`try_*`)
    /// paths. A single-rank ring sends nothing, so the flag is inert
    /// there.
    pub fn poison_next_send(&mut self) {
        self.poison_next = true;
    }

    /// In-place sum-all-reduce over `data` viewed as `rows × cols`
    /// (row-major). All ranks must call with equal shapes. `quant`
    /// selects the wire format. Returns wire bytes sent by this rank.
    pub fn allreduce(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> u64 {
        self.allreduce_seg(data, rows, cols, quant, 1)
    }

    /// Supervised [`RingHandle::allreduce`]: surfaces peer death and
    /// wire corruption as [`EngineError`] instead of panicking.
    pub fn try_allreduce(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> Result<u64, EngineError> {
        self.try_allreduce_seg(data, rows, cols, quant, 1)
    }

    /// Segment-streamed all-reduce: every hop's chunk moves as
    /// `segments` double-buffered sub-messages (see module docs).
    /// Bit-identical to `allreduce` for every `segments >= 1`.
    pub fn allreduce_seg(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
    ) -> u64 {
        self.allreduce_seg_with(data, rows, cols, quant, segments, |_, _, _| {})
    }

    /// Supervised [`RingHandle::allreduce_seg`].
    pub fn try_allreduce_seg(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
    ) -> Result<u64, EngineError> {
        self.try_allreduce_seg_with(data, rows, cols, quant, segments, |_, _, _| {})
    }

    /// Like [`RingHandle::allreduce_seg`], invoking `on_final(row_start,
    /// row_end, values)` the moment each contiguous row-range of the
    /// result becomes final on this rank — the rank's own reduced chunk
    /// right after the reduce-scatter phase, then every received
    /// sub-message during the all-gather. Ranges are non-empty, disjoint,
    /// and cover `[0, rows)` exactly, so a consumer can stream the result
    /// out (e.g. the coordinator's per-segment epilogues) without waiting
    /// for the tail of the collective. Returns wire bytes sent by this
    /// rank.
    ///
    /// # Examples
    ///
    /// Streaming the reduced rows out while the collective's tail is
    /// still on the ring:
    ///
    /// ```
    /// use iso::collective::run_on_ring;
    /// use iso::config::CommQuant;
    ///
    /// // Two ranks each contribute a 4×2 tensor of ones and twos.
    /// let results = run_on_ring(2, |r, h| {
    ///     let mut data = vec![r as f32 + 1.0; 8];
    ///     let mut rows_seen = 0;
    ///     h.allreduce_seg_with(&mut data, 4, 2, CommQuant::F32, 2, |a, b, vals| {
    ///         assert_eq!(vals.len(), (b - a) * 2);
    ///         rows_seen += b - a;
    ///     });
    ///     (data, rows_seen)
    /// });
    /// for (data, rows_seen) in results {
    ///     assert_eq!(rows_seen, 4); // every row finalized exactly once
    ///     assert!(data.iter().all(|&x| x == 3.0)); // 1 + 2 everywhere
    /// }
    /// ```
    pub fn allreduce_seg_with<F>(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
        on_final: F,
    ) -> u64
    where
        F: FnMut(usize, usize, &[f32]),
    {
        self.try_allreduce_seg_with(data, rows, cols, quant, segments, on_final)
            .expect("ring peer hung up")
    }

    /// Supervised [`RingHandle::allreduce_seg_with`]: identical wire
    /// motion and callback contract, but a dead peer or a poisoned
    /// segment returns [`EngineError`] instead of panicking, so the
    /// engine's comm threads can exit cleanly and report the failure.
    pub fn try_allreduce_seg_with<F>(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
        mut on_final: F,
    ) -> Result<u64, EngineError>
    where
        F: FnMut(usize, usize, &[f32]),
    {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        assert!(segments >= 1, "segments must be >= 1");
        if self.n == 1 || data.is_empty() {
            if !data.is_empty() {
                on_final(0, rows, data);
            }
            return Ok(0);
        }
        let n = self.n;
        let r = self.rank;
        let before = self.sent_bytes;

        // --- reduce-scatter: after n-1 steps rank r owns chunk (r+1)%n.
        let mut noop = |_: usize, _: usize, _: &[f32]| {};
        for s in 0..n - 1 {
            let send_i = (r + n - s) % n;
            let recv_i = (r + n - s - 1) % n;
            let send_rows = seg_range(rows, n, send_i);
            let recv_rows = seg_range(rows, n, recv_i);
            self.stream_step(data, cols, send_rows, recv_rows, segments, true, quant, &mut noop)?;
        }

        // This rank's chunk is now fully reduced — stream it out first.
        let own = (r + 1) % n;
        let (oa, ob) = seg_range(rows, n, own);
        if ob > oa {
            on_final(oa, ob, &data[oa * cols..ob * cols]);
        }

        // --- all-gather: broadcast the reduced chunks around the ring;
        // every received sub-message is final.
        for s in 0..n - 1 {
            let send_i = (r + 1 + n - s) % n;
            let recv_i = (r + n - s) % n;
            let send_rows = seg_range(rows, n, send_i);
            let recv_rows = seg_range(rows, n, recv_i);
            self.stream_step(
                data, cols, send_rows, recv_rows, segments, false, quant, &mut on_final,
            )?;
        }
        Ok(self.sent_bytes - before)
    }

    /// One ring step with double-buffered sub-message streaming: send the
    /// `send_rows` chunk as up to `segments` sub-messages while receiving
    /// (and reducing with `add`, or overwriting without) the `recv_rows`
    /// chunk, keeping one message in flight ahead of the reduction.
    /// `on_recv` fires for every applied sub-range. Empty chunks (rows <
    /// ring size) transfer nothing — both sides derive the sub-message
    /// count from the same chunk shape, so the ring stays in lockstep.
    #[allow(clippy::too_many_arguments)]
    fn stream_step<F>(
        &mut self,
        data: &mut [f32],
        cols: usize,
        send_rows: (usize, usize),
        recv_rows: (usize, usize),
        segments: usize,
        add: bool,
        quant: CommQuant,
        on_recv: &mut F,
    ) -> Result<(), EngineError>
    where
        F: FnMut(usize, usize, &[f32]),
    {
        let (sa, sb) = send_rows;
        let (ra, rb) = recv_rows;
        let ns = segments.min(sb - sa);
        let nr = segments.min(rb - ra);
        for k in 0..ns.max(nr + 1) {
            if k < ns {
                let (a, b) = seg_range(sb - sa, ns, k);
                let (s0, s1) = (sa + a, sa + b);
                self.send_segment(&data[s0 * cols..s1 * cols], s1 - s0, cols, quant)?;
            }
            if k >= 1 && k - 1 < nr {
                let (a, b) = seg_range(rb - ra, nr, k - 1);
                let (r0, r1) = (ra + a, ra + b);
                self.recv_apply(&mut data[r0 * cols..r1 * cols], r1 - r0, cols, add)?;
                on_recv(r0, r1, &data[r0 * cols..r1 * cols]);
            }
        }
        Ok(())
    }

    fn send_segment(
        &mut self,
        seg: &[f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> Result<(), EngineError> {
        let wire = match quant {
            CommQuant::Int8 => {
                let mut scales = self.pool.take_f32();
                let mut data = self.pool.take_i8();
                quantize_rows_into(seg, rows, cols, &mut scales, &mut data);
                Wire::I8 { rows, cols, scales, data }
            }
            CommQuant::Fp8 => {
                let mut data = self.pool.take_u8();
                crate::quant::fp8_encode_rows_into(seg, rows, cols, &mut data);
                Wire::Fp8 { rows, cols, data }
            }
            CommQuant::Int4 => {
                let mut scales = self.pool.take_f32();
                let mut data = self.pool.take_u8();
                crate::quant::quantize4_rows_into(seg, rows, cols, &mut scales, &mut data);
                Wire::I4 { rows, cols, scales, data }
            }
            // fp16 wire is modeled as f32 on CPU (same algorithm; the
            // byte accounting for fp16 lives in the simulator).
            CommQuant::Fp16 | CommQuant::F32 => {
                let mut buf = self.pool.take_f32();
                buf.extend_from_slice(seg);
                Wire::F32(buf)
            }
        };
        let nbytes = wire.bytes();
        self.sent_bytes += nbytes as u64;
        self.sent_msgs += 1;
        // Asynchronous-DMA link model: stamp the arrival deadline and
        // return; the receiver waits it out. Sending never blocks, so
        // this thread's next reduction overlaps the transfer.
        let arrive_at = match self.throttle {
            Some(t) => {
                let now = Instant::now();
                let start = match self.link_busy {
                    Some(busy) if busy > now => busy,
                    _ => now,
                };
                let arrive = start + Duration::from_secs_f64(t.wire_s(nbytes));
                self.link_busy = Some(arrive);
                Some(arrive)
            }
            None => None,
        };
        let poisoned = std::mem::take(&mut self.poison_next);
        self.tx_next
            .send(Packet { arrive_at, wire, poisoned })
            .map_err(|_| EngineError::RankDead { rank: (self.rank + 1) % self.n, link: "ring" })
    }

    /// Receive the next sub-message and either accumulate (`add = true`,
    /// reduce-scatter) or overwrite (`add = false`, all-gather) in place.
    /// Arrived buffers are recycled into this rank's pool.
    fn recv_apply(
        &mut self,
        out: &mut [f32],
        rows: usize,
        cols: usize,
        add: bool,
    ) -> Result<(), EngineError> {
        let pkt = self.rx_prev.recv().map_err(|_| EngineError::RankDead {
            rank: (self.rank + self.n - 1) % self.n,
            link: "ring",
        })?;
        if pkt.poisoned {
            return Err(EngineError::WireCorrupt { rank: self.rank, link: "ring" });
        }
        if let Some(at) = pkt.arrive_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
        match pkt.wire {
            Wire::F32(v) => {
                debug_assert_eq!(v.len(), rows * cols);
                if add {
                    for (o, x) in out.iter_mut().zip(&v) {
                        *o += *x;
                    }
                } else {
                    out.copy_from_slice(&v);
                }
                self.pool.put_f32(v);
            }
            Wire::I8 { rows: qr, cols: qc, scales, data } => {
                debug_assert_eq!((qr, qc), (rows, cols));
                let q = crate::quant::QuantizedRows { rows: qr, cols: qc, scales, data };
                if add {
                    crate::quant::dequantize_add(&q, out);
                } else {
                    crate::quant::dequantize_into(&q, out);
                }
                self.pool.put_f32(q.scales);
                self.pool.put_i8(q.data);
            }
            Wire::Fp8 { rows: qr, cols: qc, data } => {
                debug_assert_eq!((qr, qc), (rows, cols));
                let q = crate::quant::Fp8Rows { rows: qr, cols: qc, data };
                if add {
                    crate::quant::fp8_decode_add(&q, out);
                } else {
                    crate::quant::fp8_decode_into(&q, out);
                }
                self.pool.put_u8(q.data);
            }
            Wire::I4 { rows: qr, cols: qc, scales, data } => {
                debug_assert_eq!((qr, qc), (rows, cols));
                let q = crate::quant::Quant4Rows { rows: qr, cols: qc, scales, data };
                if add {
                    crate::quant::dequantize4_add(&q, out);
                } else {
                    crate::quant::dequantize4_into(&q, out);
                }
                self.pool.put_f32(q.scales);
                self.pool.put_u8(q.data);
            }
        }
        Ok(())
    }

    /// Fused-rows all-reduce for the decode lane (DESIGN.md §9): reduce
    /// around the ring in **rank order** (rank 0 → 1 → … → R−1), then
    /// broadcast the total back. Unlike the chunked ring, every element is
    /// accumulated in the same order regardless of which row it sits in —
    /// the order a `rows = 1` [`RingHandle::allreduce`] uses — so reducing
    /// a B-row batch in one call is **bit-identical, row for row, to B
    /// independent single-row all-reduces** (int8 included: per-row scales
    /// see the same row bytes hop for hop). The trade: each of the
    /// 2(R−1) messages carries the full payload instead of 1/R of it,
    /// which is the right trade for latency-bound decode activations —
    /// B× fewer messages and collectives than the per-sequence path.
    pub fn allreduce_rows_fused(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> u64 {
        self.try_allreduce_rows_fused(data, rows, cols, quant).expect("ring peer hung up")
    }

    /// Supervised [`RingHandle::allreduce_rows_fused`]: same rank-ordered
    /// wire motion, but peer death / poisoned segments surface as
    /// [`EngineError`].
    pub fn try_allreduce_rows_fused(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> Result<u64, EngineError> {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        if self.n == 1 || data.is_empty() {
            return Ok(0);
        }
        let n = self.n;
        let r = self.rank;
        let before = self.sent_bytes;

        // Reduce phase: partial sums flow 0 → 1 → … → n−1.
        if r > 0 {
            self.recv_apply(data, rows, cols, true)?;
        }
        if r < n - 1 {
            self.send_segment(data, rows, cols, quant)?;
        }

        // Broadcast phase: the total flows n−1 → 0 → … → n−2.
        if r == n - 1 {
            self.send_segment(data, rows, cols, quant)?;
        } else {
            self.recv_apply(data, rows, cols, false)?;
            if r + 1 != n - 1 {
                self.send_segment(data, rows, cols, quant)?;
            }
        }
        Ok(self.sent_bytes - before)
    }

    /// [`RingHandle::allreduce_seg_with`] with the callback bound to a
    /// [`FusedEpilogue`] (DESIGN.md §12): every finalized row-range is
    /// immediately residual-added (and, when configured, RMSNorm-ed and
    /// prologue-GEMM-ed) while the collective's remaining segments are
    /// still on the wire, so by the time the last sub-message lands the
    /// layer epilogue is already materialized. Bit-identical to running
    /// [`RingHandle::allreduce_seg`] first and applying the epilogue once
    /// over all rows (every epilogue stage is row-local). Returns wire
    /// bytes sent by this rank.
    ///
    /// # Examples
    ///
    /// ```
    /// use iso::collective::{run_on_ring, FusedEpilogue};
    /// use iso::config::CommQuant;
    ///
    /// let (rows, cols) = (4usize, 2usize);
    /// let results = run_on_ring(2, |r, h| {
    ///     let mut partial = vec![r as f32 + 1.0; rows * cols];
    ///     let mut residual = vec![10.0f32; rows * cols];
    ///     let mut ep = FusedEpilogue::residual_only(&mut residual, cols);
    ///     h.allreduce_seg_fused(&mut partial, rows, cols, CommQuant::F32, 2, &mut ep);
    ///     residual
    /// });
    /// for residual in results {
    ///     assert!(residual.iter().all(|&x| x == 13.0)); // 10 + (1 + 2)
    /// }
    /// ```
    pub fn allreduce_seg_fused(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
        epilogue: &mut FusedEpilogue<'_>,
    ) -> u64 {
        self.try_allreduce_seg_fused(data, rows, cols, quant, segments, epilogue)
            .expect("ring peer hung up")
    }

    /// Supervised [`RingHandle::allreduce_seg_fused`].
    pub fn try_allreduce_seg_fused(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
        segments: usize,
        epilogue: &mut FusedEpilogue<'_>,
    ) -> Result<u64, EngineError> {
        assert_eq!(epilogue.cols, cols, "epilogue width mismatch");
        assert_eq!(epilogue.residual.len(), rows * cols, "epilogue residual shape");
        self.try_allreduce_seg_with(data, rows, cols, quant, segments, |a, b, vals| {
            epilogue.apply(a, b, vals)
        })
    }

    /// Hand a spent f32 buffer back to this rank's pool (used by the
    /// coordinator's comm thread to recycle job payloads).
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        self.pool.put_f32(v);
    }

    /// (allocs, reuses) counters of this rank's buffer pool.
    pub fn pool_stats(&self) -> (u64, u64) {
        (self.pool.allocs, self.pool.reuses)
    }
}

// ---------------------------------------------------------------------------
// Fused epilogue (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Host-side row-wise RMSNorm:
/// `out[r] = x[r] · rsqrt(mean(x[r]²) + eps) ⊙ gamma`, f32 accumulation —
/// the same semantics as the engine's compiled kernel
/// (`python/compile/kernels/rmsnorm.py`, `eps = 1e-5`). Row-local by
/// construction, so applying it to any row-slice of a tensor is
/// **bit-identical** to applying it to the whole tensor — the property
/// that lets [`FusedEpilogue`] normalize segment-by-segment.
pub fn rmsnorm_rows(
    x: &[f32],
    rows: usize,
    cols: usize,
    gamma: &[f32],
    eps: f32,
    out: &mut [f32],
) {
    assert!(cols >= 1, "rmsnorm over zero-width rows");
    assert_eq!(x.len(), rows * cols, "rmsnorm input shape");
    assert_eq!(out.len(), rows * cols, "rmsnorm output shape");
    assert_eq!(gamma.len(), cols, "rmsnorm weight width");
    for (xr, or) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let mut ss = 0.0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / cols as f32 + eps).sqrt();
        for ((o, &v), &g) in or.iter_mut().zip(xr).zip(gamma) {
            *o = v * inv * g;
        }
    }
}

/// Row-major GEMM `out = a × w` (`a: rows × k`, `w: k × n`) — the
/// host-side stand-in for a next-op prologue GEMM. Each output row
/// depends only on input row `r`, so row-sliced execution is bit-identical
/// to one whole-tensor launch — the property [`FusedEpilogue`] relies on
/// to start the next op's first rows while the collective's tail is still
/// on the ring.
pub fn gemm_rows(a: &[f32], rows: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    assert!(k >= 1, "gemm over zero-width rows");
    assert!(n >= 1, "gemm with zero-width output");
    assert_eq!(a.len(), rows * k, "gemm lhs shape");
    assert_eq!(w.len(), k * n, "gemm weight shape");
    assert_eq!(out.len(), rows * n, "gemm output shape");
    for (ar, or) in a.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        or.fill(0.0);
        for (i, &x) in ar.iter().enumerate() {
            for (o, &ww) in or.iter_mut().zip(&w[i * n..(i + 1) * n]) {
                *o += x * ww;
            }
        }
    }
}

/// The next-op prologue of a [`FusedEpilogue`]: a row-sliced GEMM
/// (`weight: cols × n`) whose output row `r` depends only on epilogue row
/// `r`, so each segment's rows can start the next op immediately.
pub struct Prologue<'a> {
    /// `cols × n` row-major weight of the next op's first GEMM.
    pub weight: &'a [f32],
    /// Output width of the prologue GEMM.
    pub n: usize,
    /// `rows × n` output buffer the prologue writes into.
    pub out: &'a mut [f32],
}

/// The per-segment layer epilogue fused into a segmented all-reduce
/// (TokenWeave-style, DESIGN.md §12): residual-add, then optionally the
/// next op's RMSNorm slice and a row-sliced prologue GEMM, applied to
/// each row-range the moment the collective finalizes it
/// ([`RingHandle::allreduce_seg_fused`]). Every stage is row-local, so
/// the fused per-segment application is **bit-identical** to running the
/// full collective first and the epilogue once over all rows — pinned by
/// `rust/tests/fused_epilogue.rs` across segment counts, rank counts,
/// wire formats, and the engine's scheduler shapes.
pub struct FusedEpilogue<'a> {
    /// Residual stream the reduced rows accumulate into (`rows × cols`).
    pub residual: &'a mut [f32],
    /// Row width (the model's `d_model` in the engine).
    pub cols: usize,
    /// Optional next-op RMSNorm: `(gamma, eps)`; requires `normed`.
    pub norm: Option<(&'a [f32], f32)>,
    /// `rows × cols` output of the RMSNorm stage (post-residual rows
    /// normalized), required when `norm` is set.
    pub normed: Option<&'a mut [f32]>,
    /// Optional row-sliced prologue GEMM fed by the normed rows (or the
    /// raw residual rows when `norm` is unset).
    pub prologue: Option<Prologue<'a>>,
}

impl<'a> FusedEpilogue<'a> {
    /// An epilogue that only folds the residual-add into the collective —
    /// what the engine's comm threads run (the compiled next-op stage
    /// applies its own norm, so the engine path stays bit-exact).
    pub fn residual_only(residual: &'a mut [f32], cols: usize) -> FusedEpilogue<'a> {
        FusedEpilogue { residual, cols, norm: None, normed: None, prologue: None }
    }

    /// Apply the epilogue to the finalized rows `[row_start, row_end)`
    /// whose reduced values are `reduced` (length `(row_end − row_start) ×
    /// cols`). Safe to call per segment in any order; ranges must be
    /// disjoint (as [`RingHandle::allreduce_seg_with`] guarantees).
    pub fn apply(&mut self, row_start: usize, row_end: usize, reduced: &[f32]) {
        let cols = self.cols;
        let lo = row_start * cols;
        let hi = row_end * cols;
        debug_assert_eq!(reduced.len(), hi - lo, "reduced segment shape");
        for (o, v) in self.residual[lo..hi].iter_mut().zip(reduced) {
            *o += *v;
        }
        if let Some((gamma, eps)) = self.norm {
            let normed = self.normed.as_deref_mut().expect("norm requires a normed buffer");
            rmsnorm_rows(
                &self.residual[lo..hi],
                row_end - row_start,
                cols,
                gamma,
                eps,
                &mut normed[lo..hi],
            );
        }
        if let Some(p) = self.prologue.as_mut() {
            let src: &[f32] = match self.normed.as_deref() {
                Some(nrm) => &nrm[lo..hi],
                None => &self.residual[lo..hi],
            };
            gemm_rows(
                src,
                row_end - row_start,
                cols,
                p.weight,
                p.n,
                &mut p.out[row_start * p.n..row_end * p.n],
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Pipeline-stage point-to-point transport (DESIGN.md §11)
// ---------------------------------------------------------------------------

/// One inter-stage activation message: a `rows × cols` f32 tensor moved
/// verbatim (no quantization — stage handoffs are **bit-exact** by
/// construction; see DESIGN.md §11).
struct P2pPacket {
    /// Modeled arrival deadline under [`Throttle`] (None = memory speed).
    arrive_at: Option<Instant>,
    rows: usize,
    cols: usize,
    data: Vec<f32>,
    /// Fault injection: a modeled CRC failure on the stage link.
    poisoned: bool,
}

/// A rank's endpoint on the inter-stage activation chain (DESIGN.md §11).
///
/// Pipeline parallelism connects stage `s`'s TP rank `r` to stage
/// `s + 1`'s rank `r`: after a stage's final MLP all-reduce every TP rank
/// holds the identical (replicated) activation, so each rank forwards its
/// own copy to its same-index peer downstream — rank-ordered and
/// **bit-exact** (f32 moved verbatim, no re-reduction, no quantization).
///
/// Transfers are zero-copy: [`StagePort::send_next`] moves the
/// activation's own buffer onto the wire and the receiver adopts it as
/// the chunk's live activation tensor, so the p2p path allocates nothing
/// beyond what compute already produced (this supersedes the ring's
/// [`BufferPool`] recycling — there is no copy to pool). The link uses
/// the same asynchronous-DMA [`Throttle`] model as the ring: the sender
/// stamps an arrival deadline and returns; the receiver sleeps it out, so
/// upstream compute genuinely overlaps the inter-stage wire time.
pub struct StagePort {
    /// This port's stage index.
    pub stage: usize,
    /// Total pipeline stages.
    pub stages: usize,
    tx_next: Option<Sender<P2pPacket>>,
    rx_prev: Option<Receiver<P2pPacket>>,
    /// Optional emulated link speed (same model as the ring's).
    pub throttle: Option<Throttle>,
    /// When this port's outgoing link frees up (throttled mode).
    link_busy: Option<Instant>,
    /// Activation bytes this port has sent downstream.
    pub sent_bytes: u64,
    /// Activation messages this port has sent downstream.
    pub sent_msgs: u64,
    /// Fault injection: flag the next outgoing activation corrupt.
    poison_next: bool,
}

impl StagePort {
    /// A port with no neighbors (the `pp_stages = 1` degenerate chain).
    pub fn solo() -> StagePort {
        StagePort {
            stage: 0,
            stages: 1,
            tx_next: None,
            rx_prev: None,
            throttle: None,
            link_busy: None,
            sent_bytes: 0,
            sent_msgs: 0,
            poison_next: false,
        }
    }

    /// Fault injection: flag this port's next downstream activation as
    /// corrupt (a modeled CRC failure); the downstream stage's
    /// [`StagePort::try_recv_prev`] surfaces
    /// [`EngineError::WireCorrupt`]. Inert on the last stage (no
    /// downstream link).
    pub fn poison_next_send(&mut self) {
        self.poison_next = true;
    }

    /// Whether an upstream stage feeds this port.
    pub fn has_prev(&self) -> bool {
        self.rx_prev.is_some()
    }

    /// Whether a downstream stage consumes this port's sends.
    pub fn has_next(&self) -> bool {
        self.tx_next.is_some()
    }

    /// Send a `rows × cols` activation to the next stage, transferring
    /// ownership of the buffer (zero-copy, bit-exact). Never blocks: the
    /// arrival deadline is stamped and the transfer "flies" while this
    /// rank computes its next chunk.
    pub fn send_next(&mut self, data: Vec<f32>, rows: usize, cols: usize) {
        self.try_send_next(data, rows, cols).expect("stage peer hung up");
    }

    /// Supervised [`StagePort::send_next`]: a dead downstream stage
    /// returns [`EngineError::RankDead`] (the `rank` field carries the
    /// downstream **stage index**; the coordinator maps it to a global
    /// rank). Calling on the last stage is still a programming-error
    /// panic.
    pub fn try_send_next(
        &mut self,
        data: Vec<f32>,
        rows: usize,
        cols: usize,
    ) -> Result<(), EngineError> {
        assert_eq!(data.len(), rows * cols, "stage send shape mismatch");
        let tx = self.tx_next.as_ref().expect("send_next on the last stage");
        let nbytes = data.len() * 4;
        self.sent_bytes += nbytes as u64;
        self.sent_msgs += 1;
        let arrive_at = match self.throttle {
            Some(t) => {
                let now = Instant::now();
                let start = match self.link_busy {
                    Some(busy) if busy > now => busy,
                    _ => now,
                };
                let arrive = start + Duration::from_secs_f64(t.wire_s(nbytes));
                self.link_busy = Some(arrive);
                Some(arrive)
            }
            None => None,
        };
        let poisoned = std::mem::take(&mut self.poison_next);
        tx.send(P2pPacket { arrive_at, rows, cols, data, poisoned })
            .map_err(|_| EngineError::RankDead { rank: self.stage + 1, link: "stage" })
    }

    /// Blocking receive of the next upstream activation, in sender order
    /// (the chain is a FIFO channel). Sleeps until the modeled arrival
    /// deadline, then hands the buffer over verbatim.
    pub fn recv_prev(&mut self) -> (usize, usize, Vec<f32>) {
        self.try_recv_prev().expect("stage peer hung up")
    }

    /// Supervised [`StagePort::recv_prev`]: a dead upstream stage
    /// returns [`EngineError::RankDead`] and a poisoned activation
    /// returns [`EngineError::WireCorrupt`] (the `rank` field carries
    /// the **stage index** on this link). Calling on stage 0 is still a
    /// programming-error panic.
    pub fn try_recv_prev(&mut self) -> Result<(usize, usize, Vec<f32>), EngineError> {
        let rx = self.rx_prev.as_ref().expect("recv_prev on stage 0");
        let pkt = rx.recv().map_err(|_| EngineError::RankDead {
            rank: self.stage.saturating_sub(1),
            link: "stage",
        })?;
        if pkt.poisoned {
            return Err(EngineError::WireCorrupt { rank: self.stage, link: "stage" });
        }
        if let Some(at) = pkt.arrive_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
        Ok((pkt.rows, pkt.cols, pkt.data))
    }
}

/// Build the stage-to-stage chains of a `stages × tp` grid: the returned
/// ports are indexed `[stage][tp_rank]`, with stage `s` rank `r` wired to
/// stage `s + 1` rank `r`. A 1-stage grid has no channels at all.
pub fn stage_grid(stages: usize, tp: usize) -> Vec<Vec<StagePort>> {
    assert!(stages >= 1 && tp >= 1);
    let mut grid: Vec<Vec<StagePort>> = (0..stages)
        .map(|s| {
            (0..tp)
                .map(|_| StagePort { stage: s, stages, ..StagePort::solo() })
                .collect()
        })
        .collect();
    for s in 0..stages.saturating_sub(1) {
        for r in 0..tp {
            let (tx, rx) = channel();
            grid[s][r].tx_next = Some(tx);
            grid[s + 1][r].rx_prev = Some(rx);
        }
    }
    grid
}

/// Convenience: run `f(rank, handle)` on `n` scoped threads over a fresh
/// ring and return the per-rank results in rank order.
pub fn run_on_ring<T: Send>(
    n: usize,
    f: impl Fn(usize, &mut RingHandle) -> T + Sync,
) -> Vec<T> {
    let handles = ring(n);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut join = Vec::new();
        for (r, mut h) in handles.into_iter().enumerate() {
            let f = &f;
            join.push(scope.spawn(move || (r, f(r, &mut h))));
        }
        for j in join {
            let (r, v) = j.join().expect("ring worker panicked");
            out[r] = Some(v);
        }
    });
    out.into_iter().map(|v| v.expect("invariant: every rank joined above")).collect()
}

// ---------------------------------------------------------------------------
// Context-parallel ring pass (DESIGN.md §17)
// ---------------------------------------------------------------------------

/// One KV-shard message on the context-parallel ring: the K and V rows
/// for tokens `[row_start, row_start + rows)` of one sequence slot at one
/// layer, moved verbatim (f32, no quantization — shard handoffs are
/// **bit-exact** so CP composes with every drift pin; the precision
/// ladder applies to the *collectives inside* each group, not to the
/// shard chain).
pub struct ShardMsg {
    /// Engine slot of the sequence this shard belongs to.
    pub slot: usize,
    /// Layer the K/V rows were produced at.
    pub layer: usize,
    /// Token offset of the first row.
    pub row_start: usize,
    /// Token count of the shard.
    pub rows: usize,
    /// K rows, `rows × cols` flattened.
    pub k: Vec<f32>,
    /// V rows, `rows × cols` flattened.
    pub v: Vec<f32>,
}

/// Like [`P2pPacket`] but for the CP ring: a [`ShardMsg`] plus the
/// modeled arrival deadline and fault flag.
struct ShardPacket {
    arrive_at: Option<Instant>,
    msg: ShardMsg,
    poisoned: bool,
}

/// One context-parallel group's endpoint on the cyclic KV-shard ring
/// (DESIGN.md §17). Built on the [`StagePort`] machinery: zero-copy
/// ownership transfer, the same asynchronous-DMA [`Throttle`] model
/// (sender stamps an arrival deadline and returns; receiver sleeps it
/// out, so a group's layer compute genuinely overlaps the neighbor
/// shard's wire time), and the same typed fault surface
/// ([`EngineError::RankDead`] / [`EngineError::WireCorrupt`] with
/// `link: "cp"`).
///
/// Unlike the stage chain this is a *ring*: group `c` sends to
/// `(c + 1) % cp` and receives from `(c − 1) % cp`, so a full pass of
/// `cp − 1` hops shows every group every shard. The prefill schedule
/// only drives hops forward (group `c` needs exactly the prefix held by
/// groups `< c`), but the ring closes so a future all-gather (e.g. a
/// decode-side KV rebalance) needs no new wiring.
pub struct RingPass {
    /// This port's CP group index.
    pub group: usize,
    /// Total CP groups on the ring.
    pub groups: usize,
    tx_next: Option<Sender<ShardPacket>>,
    rx_prev: Option<Receiver<ShardPacket>>,
    /// Optional emulated link speed (same model as the ring's).
    pub throttle: Option<Throttle>,
    /// When this port's outgoing link frees up (throttled mode).
    link_busy: Option<Instant>,
    /// KV bytes this port has sent around the ring.
    pub sent_bytes: u64,
    /// Shard messages this port has sent around the ring.
    pub sent_msgs: u64,
    /// Fault injection: flag the next outgoing shard corrupt.
    poison_next: bool,
}

impl RingPass {
    /// A port with no neighbors (the `cp = 1` degenerate ring).
    pub fn solo() -> RingPass {
        RingPass {
            group: 0,
            groups: 1,
            tx_next: None,
            rx_prev: None,
            throttle: None,
            link_busy: None,
            sent_bytes: 0,
            sent_msgs: 0,
            poison_next: false,
        }
    }

    /// Whether a neighbor feeds this port (false only on the solo ring).
    pub fn has_prev(&self) -> bool {
        self.rx_prev.is_some()
    }

    /// Whether this port feeds a neighbor (false only on the solo ring).
    pub fn has_next(&self) -> bool {
        self.tx_next.is_some()
    }

    /// Fault injection: flag this port's next outgoing shard as corrupt
    /// (a modeled CRC failure); the neighbor's [`RingPass::try_recv_prev`]
    /// surfaces [`EngineError::WireCorrupt`]. Inert on the solo ring.
    pub fn poison_next_send(&mut self) {
        self.poison_next = true;
    }

    /// Send a shard to the next group, transferring ownership of the
    /// buffers (zero-copy, bit-exact). Never blocks: the arrival
    /// deadline is stamped and the transfer "flies" while this group
    /// computes its next layer.
    pub fn send_next(&mut self, msg: ShardMsg) {
        self.try_send_next(msg).expect("cp peer hung up");
    }

    /// Supervised [`RingPass::send_next`]: a dead neighbor returns
    /// [`EngineError::RankDead`] (the `rank` field carries the
    /// downstream **group index**; the coordinator maps it to a global
    /// rank). Calling on the solo ring is a programming-error panic.
    pub fn try_send_next(&mut self, msg: ShardMsg) -> Result<(), EngineError> {
        assert_eq!(msg.k.len(), msg.v.len(), "cp shard K/V shape mismatch");
        let tx = self.tx_next.as_ref().expect("send_next on a solo cp ring");
        let nbytes = (msg.k.len() + msg.v.len()) * 4;
        self.sent_bytes += nbytes as u64;
        self.sent_msgs += 1;
        let arrive_at = match self.throttle {
            Some(t) => {
                let now = Instant::now();
                let start = match self.link_busy {
                    Some(busy) if busy > now => busy,
                    _ => now,
                };
                let arrive = start + Duration::from_secs_f64(t.wire_s(nbytes));
                self.link_busy = Some(arrive);
                Some(arrive)
            }
            None => None,
        };
        let poisoned = std::mem::take(&mut self.poison_next);
        tx.send(ShardPacket { arrive_at, msg, poisoned }).map_err(|_| EngineError::RankDead {
            rank: (self.group + 1) % self.groups,
            link: "cp",
        })
    }

    /// Blocking receive of the previous group's next shard, in sender
    /// order (the hop is a FIFO channel). Sleeps until the modeled
    /// arrival deadline, then hands the buffers over verbatim.
    pub fn recv_prev(&mut self) -> ShardMsg {
        self.try_recv_prev().expect("cp peer hung up")
    }

    /// Supervised [`RingPass::recv_prev`]: a dead neighbor returns
    /// [`EngineError::RankDead`] and a poisoned shard returns
    /// [`EngineError::WireCorrupt`] (the `rank` field carries the
    /// **group index** on this link). Calling on the solo ring is a
    /// programming-error panic.
    pub fn try_recv_prev(&mut self) -> Result<ShardMsg, EngineError> {
        let rx = self.rx_prev.as_ref().expect("recv_prev on a solo cp ring");
        let pkt = rx.recv().map_err(|_| EngineError::RankDead {
            rank: (self.group + self.groups - 1) % self.groups,
            link: "cp",
        })?;
        if pkt.poisoned {
            return Err(EngineError::WireCorrupt { rank: self.group, link: "cp" });
        }
        if let Some(at) = pkt.arrive_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
        Ok(pkt.msg)
    }
}

/// Build the cyclic KV-shard ring of a `cp`-group grid: port `c` sends
/// to `(c + 1) % cp` and receives from `(c − 1) % cp`. A 1-group ring
/// has no channels at all (every send/recv is a programming error,
/// exactly like [`StagePort::solo`] — the `cp = 1` engine never touches
/// the ring, which is what keeps it byte-identical to the pre-CP
/// engine).
pub fn cp_ring(groups: usize) -> Vec<RingPass> {
    assert!(groups >= 1);
    if groups == 1 {
        return vec![RingPass::solo()];
    }
    let mut ports: Vec<RingPass> =
        (0..groups).map(|c| RingPass { group: c, groups, ..RingPass::solo() }).collect();
    for c in 0..groups {
        let (tx, rx) = channel();
        ports[c].tx_next = Some(tx);
        ports[(c + 1) % groups].rx_prev = Some(rx);
    }
    ports
}

/// Running state of an online-softmax accumulation for one query row
/// (DESIGN.md §17): the row max `m`, the exp-sum `l`, and the
/// unnormalized weighted-V accumulator `o` — the flash/ring-attention
/// invariant `softmax(s) · V = o / l` once every shard is absorbed.
#[derive(Clone, Debug)]
pub struct SoftmaxState {
    /// Max score seen so far (−∞ before any shard).
    pub m: f32,
    /// Exp-sum of scores, rescaled to the current max.
    pub l: f32,
    /// Unnormalized output accumulator, `head_dim` long.
    pub o: Vec<f32>,
}

impl SoftmaxState {
    /// The empty state (absorbing into it copies the other side).
    pub fn new(head_dim: usize) -> SoftmaxState {
        SoftmaxState { m: f32::NEG_INFINITY, l: 0.0, o: vec![0.0; head_dim] }
    }

    /// Merge another shard's partial state into this one — the
    /// numerically-stable two-way online-softmax combine. Associative
    /// but **not** bitwise-commutative (f32 rescales reorder), which is
    /// why [`merge_shards`] pins the combine order.
    pub fn merge(&mut self, other: &SoftmaxState) {
        if other.l == 0.0 {
            return;
        }
        if self.l == 0.0 {
            self.m = other.m;
            self.l = other.l;
            self.o.copy_from_slice(&other.o);
            return;
        }
        let m = self.m.max(other.m);
        let sa = (self.m - m).exp();
        let sb = (other.m - m).exp();
        self.l = self.l * sa + other.l * sb;
        for (o, &x) in self.o.iter_mut().zip(other.o.iter()) {
            *o = *o * sa + x * sb;
        }
        self.m = m;
    }

    /// Finalize: the attention output row `o / l` (zeros if no shard
    /// ever matched — an empty causal window).
    pub fn finish(&self) -> Vec<f32> {
        if self.l == 0.0 {
            return vec![0.0; self.o.len()];
        }
        self.o.iter().map(|&x| x / self.l).collect()
    }
}

/// Partial attention of one query row over one KV shard: scores
/// `scale · q·kⱼ` for every shard row `j`, folded into a fresh
/// [`SoftmaxState`]. `k`/`v` are `rows × head_dim` flattened.
pub fn attn_partial(q: &[f32], k: &[f32], v: &[f32], rows: usize, scale: f32) -> SoftmaxState {
    let d = q.len();
    assert_eq!(k.len(), rows * d, "K shard shape");
    assert_eq!(v.len(), rows * d, "V shard shape");
    let mut st = SoftmaxState::new(d);
    if rows == 0 {
        return st;
    }
    let scores: Vec<f32> = (0..rows)
        .map(|j| scale * q.iter().zip(&k[j * d..(j + 1) * d]).map(|(a, b)| a * b).sum::<f32>())
        .collect();
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut l = 0.0;
    for (j, &s) in scores.iter().enumerate() {
        let w = (s - m).exp();
        l += w;
        for (o, &x) in st.o.iter_mut().zip(&v[j * d..(j + 1) * d]) {
            *o += w * x;
        }
    }
    st.m = m;
    st.l = l;
    st
}

/// Combine per-shard partial states in **pinned shard order** (0, 1, …,
/// cp−1) regardless of arrival order, and finalize. This is the CP
/// determinism contract: the merge is associative but f32 rescaling is
/// not bitwise-commutative, so fixing the fold order makes the merged
/// row a pure function of the shard contents — two runs whose shards
/// arrive in different orders still produce bit-identical output
/// (pinned by `cp_merge_order_is_deterministic` below).
pub fn merge_shards(states: &[SoftmaxState]) -> Vec<f32> {
    assert!(!states.is_empty(), "merge_shards needs at least one shard");
    let mut acc = SoftmaxState::new(states[0].o.len());
    for st in states {
        acc.merge(st);
    }
    acc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    fn gold_sum(parts: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0.0f32; parts[0].len()];
        for p in parts {
            for (o, x) in out.iter_mut().zip(p) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn seg_ranges_partition_rows() {
        for rows in [1usize, 5, 16, 17, 64] {
            for n in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                for i in 0..n {
                    let (a, b) = seg_range(rows, n, i);
                    assert_eq!(a, covered, "rows={rows} n={n} i={i}");
                    covered = b;
                }
                assert_eq!(covered, rows);
            }
        }
    }

    #[test]
    fn prop_seg_range_partitions_exactly() {
        // Satellite: segments partition rows exactly — no overlap, no gap
        // — for rows < n and rows ≫ n alike, and sizes differ by ≤ 1.
        Prop::new(71).cases(300).run("seg_range partitions", |rng| {
            let rows = rng.range(0, 2000);
            let n = rng.range(1, 40);
            let mut covered = 0;
            let mut min_len = usize::MAX;
            let mut max_len = 0usize;
            for i in 0..n {
                let (a, b) = seg_range(rows, n, i);
                if a != covered || b < a {
                    return Err(format!("rows={rows} n={n} i={i}: range ({a},{b})"));
                }
                min_len = min_len.min(b - a);
                max_len = max_len.max(b - a);
                covered = b;
            }
            if covered != rows {
                return Err(format!("rows={rows} n={n}: covered {covered}"));
            }
            if max_len - min_len > 1 {
                return Err(format!("rows={rows} n={n}: skew {min_len}..{max_len}"));
            }
            Ok(())
        });
    }

    #[test]
    fn f32_allreduce_exact() {
        for n in [1usize, 2, 3, 4, 8] {
            let mut rng = Rng::new(100 + n as u64);
            let (rows, cols) = (13, 7); // deliberately not divisible by n
            let parts: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
            let want = gold_sum(&parts);
            let results = run_on_ring(n, |r, h| {
                let mut data = parts[r].clone();
                h.allreduce(&mut data, rows, cols, CommQuant::F32);
                data
            });
            for (r, got) in results.iter().enumerate() {
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "n={n} rank={r} idx={i}: {g} != {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree_bitwise_f32() {
        let n = 4;
        let mut rng = Rng::new(7);
        let parts: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(64, 1.0)).collect();
        let results = run_on_ring(n, |r, h| {
            let mut data = parts[r].clone();
            h.allreduce(&mut data, 8, 8, CommQuant::F32);
            data
        });
        for r in 1..n {
            assert_eq!(results[0], results[r], "rank {r} differs from rank 0");
        }
    }

    #[test]
    fn segmented_matches_gold_all_quants() {
        for quant in
            [CommQuant::F32, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4]
        {
            for segments in [1usize, 2, 3, 8] {
                let n = 3;
                let (rows, cols) = (10, 6);
                let mut rng = Rng::new(500 + segments as u64);
                let parts: Vec<Vec<f32>> =
                    (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
                let want = gold_sum(&parts);
                let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                // Loose plumbing tolerances; the tight per-rung analytic
                // bounds are pinned in tests/wire_precision.rs. Lower
                // rungs get an absolute term scaled by the largest
                // partial-sum magnitude (pmax · n) since per-hop error
                // tracks the values on the wire, not the final sum.
                let pmax = parts
                    .iter()
                    .flat_map(|p| p.iter())
                    .fold(0.0f32, |m, v| m.max(v.abs()));
                let tol = match quant {
                    CommQuant::Int8 => amax * 0.05,
                    CommQuant::Fp8 => 0.30 * n as f32 * pmax,
                    CommQuant::Int4 => 0.30 * n as f32 * pmax,
                    _ => 1e-4,
                };
                let results = run_on_ring(n, |r, h| {
                    let mut d = parts[r].clone();
                    h.allreduce_seg(&mut d, rows, cols, quant, segments);
                    d
                });
                for got in &results {
                    for (g, w) in got.iter().zip(&want) {
                        assert!(
                            (g - w).abs() <= tol,
                            "quant={quant:?} segments={segments}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn int8_allreduce_bounded_error() {
        let n = 4;
        let (rows, cols) = (16, 32);
        let mut rng = Rng::new(9);
        let parts: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let want = gold_sum(&parts);
        let results = run_on_ring(n, |r, h| {
            let mut data = parts[r].clone();
            h.allreduce(&mut data, rows, cols, CommQuant::Int8);
            data
        });
        // Error accumulates over ~2(R-1) quantized hops; bound loosely.
        let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let tol = amax * 0.05;
        for got in &results {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= tol, "{g} vs {w} (tol {tol})");
            }
        }
    }

    #[test]
    fn int8_wire_bytes_quarter_of_f32() {
        let n = 4;
        let (rows, cols) = (64, 128);
        let data = vec![1.0f32; rows * cols];
        let bytes = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce(&mut d, rows, cols, CommQuant::F32)
        });
        let bytes_q = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce(&mut d, rows, cols, CommQuant::Int8)
        });
        for (f, q) in bytes.iter().zip(&bytes_q) {
            let ratio = *q as f64 / *f as f64;
            assert!((0.24..0.30).contains(&ratio), "wire ratio {ratio}");
        }
    }

    #[test]
    fn wire_bytes_count_scales_and_packing() {
        // Accounting audit (PR 8 satellite): every Wire variant must
        // count its scale vector, and int4 must count ceil(cols/2)
        // packed bytes per row. 8×17 picked for the odd-cols edge; all
        // numbers below are hand arithmetic.
        let (rows, cols) = (8usize, 17usize);
        let f = Wire::F32(vec![0.0; rows * cols]);
        assert_eq!(f.bytes(), 8 * 17 * 4); // 544
        let i8w =
            Wire::I8 { rows, cols, scales: vec![0.0; rows], data: vec![0; rows * cols] };
        assert_eq!(i8w.bytes(), 8 * 4 + 8 * 17); // 32 scale + 136 data
        let f8 = Wire::Fp8 { rows, cols, data: vec![0; rows * cols] };
        assert_eq!(f8.bytes(), 8 * 17); // no scales: 136
        let i4 = Wire::I4 { rows, cols, scales: vec![0.0; rows], data: vec![0; rows * 9] };
        assert_eq!(i4.bytes(), 8 * 4 + 8 * 9); // 32 scale + 72 packed
        // The config-side table (used by the sched cost model and the
        // BENCH_PRECISION bytes columns) must agree exactly.
        for (q, want) in [
            (CommQuant::F32, 544),
            (CommQuant::Fp16, 544), // fp16 moves raw f32 on the CPU wire
            (CommQuant::Int8, 168),
            (CommQuant::Fp8, 136),
            (CommQuant::Int4, 104),
        ] {
            assert_eq!(q.wire_bytes(rows, cols), want, "{q:?}");
        }
    }

    #[test]
    fn fused_ring_bytes_match_wire_table() {
        // Measured fused-lane traffic is exactly 2(R−1) full-payload
        // messages at the rung's wire size — the counters feeding
        // comm_bytes and BENCH_PRECISION.json are trustworthy per rung.
        let n = 3;
        let (rows, cols) = (4usize, 17usize);
        for q in [CommQuant::F32, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4] {
            let sent = run_on_ring(n, |r, h| {
                let mut d = vec![r as f32 + 1.0; rows * cols];
                h.allreduce_rows_fused(&mut d, rows, cols, q)
            });
            let total: u64 = sent.iter().sum();
            assert_eq!(
                total,
                2 * (n as u64 - 1) * q.wire_bytes(rows, cols) as u64,
                "{q:?}"
            );
        }
    }

    #[test]
    fn segmentation_moves_same_bytes() {
        // Sub-message streaming changes granularity, not volume.
        let n = 4;
        let (rows, cols) = (64, 32);
        let data = vec![0.5f32; rows * cols];
        let mono = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 1)
        });
        let seg = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 8)
        });
        assert_eq!(mono, seg, "wire bytes must not depend on segmentation");
    }

    #[test]
    fn single_rank_is_identity() {
        let mut h = ring(1).pop().unwrap();
        let mut data = vec![1.0, 2.0, 3.0, 4.0];
        let sent = h.allreduce(&mut data, 2, 2, CommQuant::F32);
        assert_eq!(sent, 0);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn back_to_back_allreduces_stay_consistent() {
        // The engine issues two all-reduces per layer; make sure ring
        // state can be reused without cross-talk.
        let n = 3;
        let results = run_on_ring(n, |r, h| {
            let mut a = vec![r as f32; 6];
            h.allreduce(&mut a, 2, 3, CommQuant::F32);
            let mut b = vec![(r + 1) as f32; 6];
            h.allreduce(&mut b, 3, 2, CommQuant::F32);
            (a, b)
        });
        for (a, b) in &results {
            assert!(a.iter().all(|&x| x == 3.0)); // 0+1+2
            assert!(b.iter().all(|&x| x == 6.0)); // 1+2+3
        }
    }

    #[test]
    fn pool_recycles_buffers_across_allreduces() {
        // Buffers circulate the ring: after a warmup lap the pool serves
        // every send, so repeated collectives stop allocating.
        let n = 4;
        let (rows, cols) = (16, 8);
        let stats = run_on_ring(n, |r, h| {
            let mut d = vec![r as f32; rows * cols];
            h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 2);
            let (allocs_warm, _) = h.pool_stats();
            for _ in 0..8 {
                h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 2);
            }
            let (allocs, reuses) = h.pool_stats();
            (allocs_warm, allocs, reuses)
        });
        for (allocs_warm, allocs, reuses) in stats {
            assert!(reuses > 0, "pool never reused a buffer");
            // Steady state: at most one extra lap of allocations beyond
            // the warmup round (receivers may briefly lag senders).
            assert!(
                allocs <= allocs_warm * 2 + 2,
                "allocations keep growing: warm={allocs_warm} total={allocs}"
            );
        }
    }

    #[test]
    fn on_final_ranges_cover_rows_exactly() {
        for n in [1usize, 2, 3, 4] {
            for segments in [1usize, 3] {
                let (rows, cols) = (11, 5);
                let covered = run_on_ring(n, |r, h| {
                    let mut d = vec![r as f32 + 1.0; rows * cols];
                    let mut seen = vec![0u32; rows];
                    h.allreduce_seg_with(
                        &mut d,
                        rows,
                        cols,
                        CommQuant::F32,
                        segments,
                        |a, b, vals| {
                            assert_eq!(vals.len(), (b - a) * cols);
                            assert!(b > a, "empty on_final range");
                            for row in &mut seen[a..b] {
                                *row += 1;
                            }
                        },
                    );
                    (d, seen)
                });
                let want: f32 = (1..=n).map(|x| x as f32).sum();
                for (d, seen) in covered {
                    assert!(seen.iter().all(|&c| c == 1), "n={n} segs={segments}: {seen:?}");
                    assert!(d.iter().all(|&x| x == want));
                }
            }
        }
    }

    #[test]
    fn on_final_values_match_result() {
        let n = 3;
        let (rows, cols) = (9, 4);
        let mut rng = Rng::new(77);
        let parts: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let results = run_on_ring(n, |r, h| {
            let mut d = parts[r].clone();
            let mut streamed = vec![f32::NAN; rows * cols];
            h.allreduce_seg_with(&mut d, rows, cols, CommQuant::F32, 2, |a, _b, vals| {
                streamed[a * cols..a * cols + vals.len()].copy_from_slice(vals);
            });
            (d, streamed)
        });
        for (d, streamed) in results {
            assert_eq!(d, streamed, "streamed rows differ from final result");
        }
    }

    #[test]
    fn fused_rows_bit_identical_to_per_row() {
        // The PR-2 invariant: reducing a B-row decode lane in one fused
        // call equals B independent single-row all-reduces bit for bit,
        // for every wire rung (per-row int8/int4 scales are row-local,
        // fp8 is elementwise, int4 packing restarts each row, and the
        // per-element accumulation order matches rank order in all).
        for quant in
            [CommQuant::F32, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4]
        {
            for n in [2usize, 3, 4] {
                for rows in [1usize, 3, 8] {
                    let cols = 16;
                    let mut rng = Rng::new(900 + n as u64 * 10 + rows as u64);
                    let parts: Vec<Vec<f32>> =
                        (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
                    let fused = run_on_ring(n, |r, h| {
                        let mut d = parts[r].clone();
                        h.allreduce_rows_fused(&mut d, rows, cols, quant);
                        d
                    });
                    let per_row = run_on_ring(n, |r, h| {
                        let mut d = parts[r].clone();
                        for j in 0..rows {
                            let mut row = d[j * cols..(j + 1) * cols].to_vec();
                            h.allreduce(&mut row, 1, cols, quant);
                            d[j * cols..(j + 1) * cols].copy_from_slice(&row);
                        }
                        d
                    });
                    for (r, (f, p)) in fused.iter().zip(&per_row).enumerate() {
                        assert_eq!(
                            f, p,
                            "quant={quant:?} n={n} rows={rows} rank={r}: fused != per-row"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_rows_sends_b_times_fewer_messages() {
        let n = 4;
        let (rows, cols) = (8, 16);
        let data = vec![1.0f32; rows * cols];
        let fused_msgs = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce_rows_fused(&mut d, rows, cols, CommQuant::F32);
            h.sent_msgs
        });
        let per_row_msgs = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            for j in 0..rows {
                let mut row = d[j * cols..(j + 1) * cols].to_vec();
                h.allreduce(&mut row, 1, cols, CommQuant::F32);
                d[j * cols..(j + 1) * cols].copy_from_slice(&row);
            }
            h.sent_msgs
        });
        let fused_total: u64 = fused_msgs.iter().sum();
        let per_row_total: u64 = per_row_msgs.iter().sum();
        assert_eq!(fused_total, 2 * (n as u64 - 1), "fused ring messages");
        assert_eq!(per_row_total, rows as u64 * fused_total, "B× message saving");
    }

    #[test]
    fn fused_rows_single_rank_is_identity() {
        let mut h = ring(1).pop().unwrap();
        let mut data = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(h.allreduce_rows_fused(&mut data, 2, 2, CommQuant::F32), 0);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn prop_fused_rows_matches_gold() {
        Prop::new(43).cases(30).run("fused rows == serial sum", |rng| {
            let n = rng.range(2, 6);
            let rows = rng.range(1, 12);
            let cols = rng.range(1, 20);
            let parts: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(rows * cols, 2.0)).collect();
            let want = gold_sum(&parts);
            let results = run_on_ring(n, |r, h| {
                let mut d = parts[r].clone();
                h.allreduce_rows_fused(&mut d, rows, cols, CommQuant::F32);
                d
            });
            for got in &results {
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > 1e-3 * w.abs().max(1.0) {
                        return Err(format!("{g} != {w} (n={n} rows={rows} cols={cols})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn stage_grid_wires_a_linear_chain() {
        let grid = stage_grid(3, 2);
        assert_eq!(grid.len(), 3);
        for (s, row) in grid.iter().enumerate() {
            assert_eq!(row.len(), 2);
            for p in row {
                assert_eq!((p.stage, p.stages), (s, 3));
                assert_eq!(p.has_prev(), s > 0);
                assert_eq!(p.has_next(), s < 2);
            }
        }
        let solo = stage_grid(1, 4);
        assert!(solo[0].iter().all(|p| !p.has_prev() && !p.has_next()));
    }

    #[test]
    fn stage_port_transfers_bit_exact_in_order() {
        // Two tensors sent down a 2-stage chain arrive FIFO and bitwise
        // identical — the DESIGN.md §11 handoff invariant.
        let mut grid = stage_grid(2, 1);
        let mut tail = grid.pop().unwrap().pop().unwrap();
        let mut head = grid.pop().unwrap().pop().unwrap();
        let mut rng = Rng::new(11);
        let a = rng.normal_vec(6 * 5, 3.0);
        let b = rng.normal_vec(2 * 5, 3.0);
        head.send_next(a.clone(), 6, 5);
        head.send_next(b.clone(), 2, 5);
        let (r0, c0, got_a) = tail.recv_prev();
        let (r1, c1, got_b) = tail.recv_prev();
        assert_eq!((r0, c0), (6, 5));
        assert_eq!((r1, c1), (2, 5));
        assert_eq!(got_a, a, "first tensor corrupted in flight");
        assert_eq!(got_b, b, "second tensor corrupted in flight");
        assert_eq!(head.sent_msgs, 2);
        assert_eq!(head.sent_bytes, ((6 * 5 + 2 * 5) * 4) as u64);
    }

    #[test]
    fn prop_stage_chain_round_trips_bit_exactly() {
        // Satellite (PR 4): arbitrary activation tensors forwarded hop by
        // hop through an arbitrary-depth stage chain come out bit-exact.
        Prop::new(83).cases(60).run("stage chain bit-exact", |rng| {
            let stages = rng.range(2, 5);
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 20);
            let data = rng.normal_vec(rows * cols, 2.0);
            let grid = stage_grid(stages, 1);
            let mut ports: Vec<StagePort> =
                grid.into_iter().map(|mut row| row.pop().unwrap()).collect();
            let sent = data.clone();
            let out = std::thread::scope(|scope| {
                let mut joins = Vec::new();
                for (s, p) in ports.iter_mut().enumerate() {
                    let sent = &sent;
                    joins.push(scope.spawn(move || {
                        if s == 0 {
                            p.send_next(sent.clone(), rows, cols);
                            None
                        } else {
                            let (r, c, d) = p.recv_prev();
                            assert_eq!((r, c), (rows, cols));
                            if p.has_next() {
                                p.send_next(d, r, c);
                                None
                            } else {
                                Some(d)
                            }
                        }
                    }));
                }
                joins.into_iter().filter_map(|j| j.join().unwrap()).next()
            });
            match out {
                Some(d) if d == sent => Ok(()),
                Some(_) => Err(format!("stages={stages}: bits changed in flight")),
                None => Err("chain produced no output".into()),
            }
        });
    }

    #[test]
    fn stage_port_throttle_delays_arrival() {
        // The async-DMA model: a throttled hop's payload is unavailable
        // before its modeled deadline, but the send itself returns
        // immediately (transfer overlaps upstream compute).
        let mut grid = stage_grid(2, 1);
        let mut tail = grid.pop().unwrap().pop().unwrap();
        let mut head = grid.pop().unwrap().pop().unwrap();
        head.throttle = Some(Throttle { alpha_s: 0.02, bytes_per_s: 1e12 });
        let t0 = Instant::now();
        head.send_next(vec![1.0; 64], 8, 8);
        let send_elapsed = t0.elapsed();
        let (_, _, d) = tail.recv_prev();
        let recv_elapsed = t0.elapsed();
        assert!(send_elapsed < Duration::from_millis(15), "send must not block");
        assert!(recv_elapsed >= Duration::from_millis(15), "arrival beat the deadline");
        assert_eq!(d, vec![1.0; 64]);
    }

    #[test]
    fn rmsnorm_rows_row_local_bitwise() {
        // Applying the norm to a row-slice equals applying it to the
        // whole tensor, bit for bit — the segment-streaming invariant.
        let (rows, cols) = (9usize, 6usize);
        let mut rng = Rng::new(31);
        let x = rng.normal_vec(rows * cols, 2.0);
        let gamma = rng.normal_vec(cols, 1.0);
        let mut whole = vec![0.0f32; rows * cols];
        rmsnorm_rows(&x, rows, cols, &gamma, 1e-5, &mut whole);
        for split in [1usize, 4, 8] {
            let mut sliced = vec![0.0f32; rows * cols];
            let (head, _) = x.split_at(split * cols);
            rmsnorm_rows(head, split, cols, &gamma, 1e-5, &mut sliced[..split * cols]);
            rmsnorm_rows(
                &x[split * cols..],
                rows - split,
                cols,
                &gamma,
                1e-5,
                &mut sliced[split * cols..],
            );
            assert_eq!(whole, sliced, "split={split}: norm not row-local");
        }
        // Sanity: unit gamma + constant rows normalize to ~±1.
        let ones = vec![1.0f32; cols];
        let threes = vec![3.0f32; cols];
        let mut out = vec![0.0f32; cols];
        rmsnorm_rows(&threes, 1, cols, &ones, 0.0, &mut out);
        for v in out {
            assert!((v - 1.0).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn gemm_rows_matches_hand_result_and_is_row_local() {
        // 2×3 × 3×2, hand-checked.
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut out = [0.0f32; 4];
        gemm_rows(&a, 2, 3, &w, 2, &mut out);
        assert_eq!(out, [4.0, 5.0, 10.0, 11.0]);
        // Row-sliced equals whole, bitwise.
        let mut rng = Rng::new(5);
        let (rows, k, n) = (7usize, 5usize, 4usize);
        let a = rng.normal_vec(rows * k, 1.5);
        let w = rng.normal_vec(k * n, 1.5);
        let mut whole = vec![0.0f32; rows * n];
        gemm_rows(&a, rows, k, &w, n, &mut whole);
        let mut sliced = vec![0.0f32; rows * n];
        for r in 0..rows {
            gemm_rows(&a[r * k..(r + 1) * k], 1, k, &w, n, &mut sliced[r * n..(r + 1) * n]);
        }
        assert_eq!(whole, sliced, "gemm not row-local");
    }

    #[test]
    fn fused_epilogue_segmented_matches_monolithic_bitwise() {
        // The PR-5 invariant at the collective layer: fusing the full
        // epilogue (residual + norm + prologue) into the per-segment
        // callbacks equals reducing first and applying once — bit for
        // bit, for every wire format and segment count.
        let (rows, cols, n_out) = (11usize, 6usize, 4usize);
        for quant in
            [CommQuant::F32, CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4]
        {
            for n in [1usize, 2, 4] {
                let mut rng = Rng::new(600 + n as u64);
                let parts: Vec<Vec<f32>> =
                    (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
                let res0 = rng.normal_vec(rows * cols, 1.0);
                let gamma = rng.normal_vec(cols, 1.0);
                let w = rng.normal_vec(cols * n_out, 1.0);
                // Gold: monolithic reduce, then one whole-tensor epilogue.
                let gold = run_on_ring(n, |r, h| {
                    let mut d = parts[r].clone();
                    h.allreduce_seg(&mut d, rows, cols, quant, 1);
                    let mut res = res0.clone();
                    let mut normed = vec![0.0f32; rows * cols];
                    let mut out = vec![0.0f32; rows * n_out];
                    let mut ep = FusedEpilogue {
                        residual: &mut res,
                        cols,
                        norm: Some((&gamma, 1e-5)),
                        normed: Some(&mut normed),
                        prologue: Some(Prologue { weight: &w, n: n_out, out: &mut out }),
                    };
                    ep.apply(0, rows, &d);
                    (res, normed, out)
                });
                for segments in [1usize, 2, 3, 8] {
                    let fused = run_on_ring(n, |r, h| {
                        let mut d = parts[r].clone();
                        let mut res = res0.clone();
                        let mut normed = vec![0.0f32; rows * cols];
                        let mut out = vec![0.0f32; rows * n_out];
                        let mut ep = FusedEpilogue {
                            residual: &mut res,
                            cols,
                            norm: Some((&gamma, 1e-5)),
                            normed: Some(&mut normed),
                            prologue: Some(Prologue { weight: &w, n: n_out, out: &mut out }),
                        };
                        h.allreduce_seg_fused(&mut d, rows, cols, quant, segments, &mut ep);
                        (res, normed, out)
                    });
                    assert_eq!(
                        gold, fused,
                        "quant={quant:?} n={n} segments={segments}: fused epilogue diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn poisoned_ring_segment_surfaces_wire_corrupt() {
        // PR-6: a poisoned wire segment is detected at the receiver as
        // WireCorrupt; the sender then observes the cascade (its dead
        // peer) as RankDead. Nothing hangs.
        let results = run_on_ring(2, |r, h| {
            if r == 0 {
                h.poison_next_send();
            }
            let mut d = vec![1.0f32; 8];
            h.try_allreduce(&mut d, 2, 4, CommQuant::F32)
        });
        assert_eq!(
            results[1],
            Err(EngineError::WireCorrupt { rank: 1, link: "ring" }),
            "receiver must flag the poisoned segment"
        );
        assert_eq!(
            results[0],
            Err(EngineError::RankDead { rank: 1, link: "ring" }),
            "sender must observe the peer's exit, not hang"
        );
    }

    #[test]
    fn poison_is_inert_on_a_single_rank_ring() {
        let mut h = ring(1).pop().unwrap();
        h.poison_next_send();
        let mut d = vec![1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(h.try_allreduce(&mut d, 2, 2, CommQuant::F32), Ok(0));
        assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn dead_ring_peer_cascades_rank_dead_without_hanging() {
        // PR-6 detection invariant (DESIGN.md §14): one rank exiting
        // before the collective unblocks every other rank with RankDead
        // via the sender-drop cascade — no recv waits forever.
        let results = run_on_ring(3, |r, h| {
            if r == 1 {
                return Ok(0); // rank 1 "dies" before the collective
            }
            let mut d = vec![r as f32; 6];
            h.try_allreduce(&mut d, 2, 3, CommQuant::F32)
        });
        assert_eq!(results[1], Ok(0));
        for r in [0usize, 2] {
            match &results[r] {
                Err(EngineError::RankDead { link: "ring", .. }) => {}
                other => panic!("rank {r}: expected RankDead, got {other:?}"),
            }
        }
    }

    #[test]
    fn poisoned_stage_activation_surfaces_wire_corrupt_then_recovers() {
        let mut grid = stage_grid(2, 1);
        let mut tail = grid.pop().unwrap().pop().unwrap();
        let mut head = grid.pop().unwrap().pop().unwrap();
        head.poison_next_send();
        head.try_send_next(vec![1.0; 4], 2, 2).unwrap();
        assert_eq!(
            tail.try_recv_prev(),
            Err(EngineError::WireCorrupt { rank: 1, link: "stage" })
        );
        // The flag is one-shot: the next activation crosses clean.
        head.try_send_next(vec![2.0; 4], 2, 2).unwrap();
        let (r, c, d) = tail.try_recv_prev().unwrap();
        assert_eq!((r, c, d), (2, 2, vec![2.0; 4]));
    }

    #[test]
    fn dead_stage_peer_surfaces_rank_dead() {
        // Upstream death: recv errors instead of hanging.
        let mut grid = stage_grid(2, 1);
        let mut tail = grid.pop().unwrap().pop().unwrap();
        let head = grid.pop().unwrap().pop().unwrap();
        drop(head);
        assert_eq!(
            tail.try_recv_prev(),
            Err(EngineError::RankDead { rank: 0, link: "stage" })
        );
        // Downstream death: send errors instead of aborting.
        let mut grid = stage_grid(2, 1);
        let tail = grid.pop().unwrap().pop().unwrap();
        let mut head = grid.pop().unwrap().pop().unwrap();
        drop(tail);
        assert_eq!(
            head.try_send_next(vec![0.0; 2], 1, 2),
            Err(EngineError::RankDead { rank: 1, link: "stage" })
        );
    }

    #[test]
    fn prop_f32_allreduce_matches_gold() {
        Prop::new(41).cases(30).run("ring == serial sum", |rng| {
            let n = rng.range(2, 6);
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 20);
            let segments = rng.range(1, 6);
            let parts: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(rows * cols, 2.0)).collect();
            let want = gold_sum(&parts);
            let results = run_on_ring(n, |r, h| {
                let mut d = parts[r].clone();
                h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, segments);
                d
            });
            for got in &results {
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > 1e-3 * w.abs().max(1.0) {
                        return Err(format!("{g} != {w} (n={n} rows={rows} cols={cols})"));
                    }
                }
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod cp_tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn cp_solo_has_no_neighbors() {
        let ports = cp_ring(1);
        assert_eq!(ports.len(), 1);
        assert!(!ports[0].has_prev() && !ports[0].has_next());
        assert_eq!((ports[0].sent_bytes, ports[0].sent_msgs), (0, 0));
    }

    #[test]
    fn cp_ring_moves_shards_in_order_and_counts_bytes() {
        // Channels are buffered, so a single thread can drive the whole
        // ring: every group sends two shards forward, then drains its
        // inbox in sender order.
        let mut ports = cp_ring(3);
        for c in 0..3 {
            for layer in 0..2 {
                let k: Vec<f32> = vec![c as f32; 4];
                let v: Vec<f32> = vec![layer as f32; 4];
                ports[c].send_next(ShardMsg { slot: 7, layer, row_start: c, rows: 2, k, v });
            }
            assert_eq!(ports[c].sent_msgs, 2);
            assert_eq!(ports[c].sent_bytes, 2 * (4 + 4) * 4);
        }
        for c in 0..3 {
            let from = (c + 2) % 3;
            for layer in 0..2 {
                let m = ports[c].recv_prev();
                assert_eq!((m.slot, m.layer, m.row_start, m.rows), (7, layer, from, 2));
                assert_eq!(m.k, vec![from as f32; 4]);
                assert_eq!(m.v, vec![layer as f32; 4]);
            }
        }
    }

    #[test]
    fn cp_prefix_chain_accumulates_forward() {
        // The prefill schedule's forward pass: group c receives the
        // prefix [0, c·2) from c−1, appends its own rows, and forwards
        // [0, (c+1)·2). The last group ends holding the full sequence.
        let mut ports = cp_ring(3);
        let own = |c: usize| -> Vec<f32> { vec![c as f32; 2 * 4] };
        let mut prefix: Vec<f32> = Vec::new();
        for c in 0..2 {
            if ports[c].has_prev() && c > 0 {
                let m = ports[c].recv_prev();
                assert_eq!(m.rows, 2 * c);
                prefix = m.k;
            }
            prefix.extend_from_slice(&own(c));
            let msg = ShardMsg {
                slot: 0,
                layer: 0,
                row_start: 0,
                rows: 2 * (c + 1),
                k: prefix.clone(),
                v: prefix.clone(),
            };
            ports[c].send_next(msg);
        }
        let m = ports[2].recv_prev();
        assert_eq!(m.rows, 4);
        let mut want = own(0);
        want.extend_from_slice(&own(1));
        assert_eq!(m.k, want);
    }

    #[test]
    fn cp_poison_surfaces_wire_corrupt() {
        let mut ports = cp_ring(2);
        ports[0].poison_next_send();
        ports[0]
            .send_next(ShardMsg { slot: 0, layer: 0, row_start: 0, rows: 1, k: vec![1.0], v: vec![2.0] });
        match ports[1].try_recv_prev() {
            Err(EngineError::WireCorrupt { rank: 1, link: "cp" }) => {}
            other => panic!("want WireCorrupt on cp link, got {other:?}"),
        }
        // The flag is one-shot: the next shard is clean.
        ports[0]
            .send_next(ShardMsg { slot: 0, layer: 1, row_start: 0, rows: 1, k: vec![3.0], v: vec![4.0] });
        assert_eq!(ports[1].recv_prev().layer, 1);
    }

    #[test]
    fn cp_dead_peer_is_rank_dead() {
        let mut ports = cp_ring(3);
        let dead = ports.remove(2); // group 2's rx drops with it
        drop(dead);
        let err = ports[1]
            .try_send_next(ShardMsg { slot: 0, layer: 0, row_start: 0, rows: 1, k: vec![0.0], v: vec![0.0] })
            .unwrap_err();
        match err {
            EngineError::RankDead { rank: 2, link: "cp" } => {}
            other => panic!("want RankDead on cp link, got {other:?}"),
        }
    }

    #[test]
    fn cp_throttled_shard_still_delivers_verbatim() {
        let mut ports = cp_ring(2);
        ports[0].throttle = Some(Throttle { alpha_s: 1e-4, bytes_per_s: 1e8 });
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        ports[0].send_next(ShardMsg { slot: 3, layer: 5, row_start: 2, rows: 2, k: k.clone(), v: k.clone() });
        let m = ports[1].recv_prev();
        assert_eq!((m.slot, m.layer, m.row_start, m.rows), (3, 5, 2, 2));
        assert_eq!(m.k, k);
    }

    /// Direct (one-pass) softmax attention for one query row — the
    /// reference the sharded online merge must agree with.
    fn direct_attention(q: &[f32], k: &[f32], v: &[f32], rows: usize, scale: f32) -> Vec<f32> {
        let d = q.len();
        let scores: Vec<f32> = (0..rows)
            .map(|j| scale * q.iter().zip(&k[j * d..(j + 1) * d]).map(|(a, b)| a * b).sum::<f32>())
            .collect();
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f32> = scores.iter().map(|&s| (s - m).exp()).collect();
        let l: f32 = ws.iter().sum();
        let mut out = vec![0.0; d];
        for (j, &w) in ws.iter().enumerate() {
            for (o, &x) in out.iter_mut().zip(&v[j * d..(j + 1) * d]) {
                *o += w * x / l;
            }
        }
        out
    }

    #[test]
    fn sharded_softmax_merge_matches_direct_attention() {
        let mut rng = Rng::new(0x5EED);
        for (rows, d, shards) in [(12, 8, 3), (7, 4, 2), (16, 16, 4), (5, 8, 5)] {
            let q = rng.normal_vec(d, 1.0);
            let k = rng.normal_vec(rows * d, 1.0);
            let v = rng.normal_vec(rows * d, 1.0);
            let scale = 1.0 / (d as f32).sqrt();
            let want = direct_attention(&q, &k, &v, rows, scale);
            // Split the rows into `shards` contiguous pieces (seg_range
            // balance, like the engine's shard bounds) and merge the
            // partials in pinned order.
            let states: Vec<SoftmaxState> = (0..shards)
                .map(|s| {
                    let (lo, hi) = seg_range(rows, shards, s);
                    attn_partial(&q, &k[lo * d..hi * d], &v[lo * d..hi * d], hi - lo, scale)
                })
                .collect();
            let got = merge_shards(&states);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "{g} vs {w} (rows={rows} shards={shards})");
            }
        }
    }

    #[test]
    fn cp_merge_order_is_deterministic() {
        // Arrival order must not leak into the output: computing the
        // partials in any order and folding them by shard index gives
        // bit-identical f32s.
        let mut rng = Rng::new(99);
        let (rows, d, shards) = (24, 8, 4);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(rows * d, 1.0);
        let v = rng.normal_vec(rows * d, 1.0);
        let scale = 0.25;
        let partial = |s: usize| {
            let (lo, hi) = seg_range(rows, shards, s);
            attn_partial(&q, &k[lo * d..hi * d], &v[lo * d..hi * d], hi - lo, scale)
        };
        let in_order: Vec<SoftmaxState> = (0..shards).map(partial).collect();
        for arrival in [[3, 1, 0, 2], [2, 3, 1, 0], [1, 0, 3, 2]] {
            let mut by_index: Vec<Option<SoftmaxState>> = (0..shards).map(|_| None).collect();
            for s in arrival {
                by_index[s] = Some(partial(s));
            }
            let states: Vec<SoftmaxState> =
                by_index.into_iter().map(|s| s.unwrap()).collect();
            let a = merge_shards(&in_order);
            let b = merge_shards(&states);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "pinned combine order must be arrival-invariant"
            );
        }
    }

    #[test]
    fn empty_shard_is_identity_in_merge() {
        let d = 4;
        let empty = SoftmaxState::new(d);
        assert_eq!(empty.finish(), vec![0.0; d]);
        let mut st = attn_partial(&[1.0; 4], &[0.5; 8], &[2.0; 8], 2, 1.0);
        let before = st.finish();
        st.merge(&SoftmaxState::new(d));
        assert_eq!(st.finish(), before);
        let mut acc = SoftmaxState::new(d);
        acc.merge(&st);
        assert_eq!(acc.finish(), before);
    }
}
