//! Real ring all-reduce across tensor-parallel worker threads.
//!
//! This is the communication that ISO overlaps. Each TP rank is a thread;
//! ranks are connected in a ring of mpsc channels (the CPU stand-in for
//! NCCL's NVLink/PCIe ring — same algorithm, same step structure:
//! reduce-scatter then all-gather, 2(R−1) steps moving 1/R of the payload
//! each).
//!
//! Wire formats (paper §3.2 "communication dominates"): `F32` sends raw
//! activations; `Int8` quantizes each hop's segment with per-row scales
//! (`quant::quantize_rows`), cutting wire bytes ~4× at a bounded, tested
//! accuracy cost — the CPU analogue of the paper's fp16→int8 compression.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::config::CommQuant;
use crate::quant::quantize_rows;

/// One hop's payload.
enum Wire {
    F32(Vec<f32>),
    I8 { rows: usize, cols: usize, scales: Vec<f32>, data: Vec<i8> },
}

impl Wire {
    fn bytes(&self) -> usize {
        match self {
            Wire::F32(v) => v.len() * 4,
            Wire::I8 { scales, data, .. } => scales.len() * 4 + data.len(),
        }
    }
}

/// Emulated link speed for the ring (DESIGN.md §2: the CPU testbed's
/// shared-memory channels are far faster than PCIe/NVLink relative to its
/// compute, so engine experiments can throttle each hop to a calibrated
/// `alpha + bytes/bandwidth` — the same α/β model the simulator uses.
/// Quantized wire formats then genuinely shrink the transfer time, exactly
/// like the paper's fp16→int8 compression on the 4090).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Throttle {
    /// Per-hop latency (seconds).
    pub alpha_s: f64,
    /// Wire bandwidth in bytes/second.
    pub bytes_per_s: f64,
}

impl Throttle {
    fn pace(&self, bytes: usize) {
        let secs = self.alpha_s + bytes as f64 / self.bytes_per_s;
        std::thread::sleep(std::time::Duration::from_secs_f64(secs));
    }
}

/// A rank's handle into the ring; moved into its worker thread.
pub struct RingHandle {
    pub rank: usize,
    pub n: usize,
    tx_next: Sender<Wire>,
    rx_prev: Receiver<Wire>,
    /// Total wire bytes this rank has sent.
    pub sent_bytes: u64,
    /// Optional emulated link speed.
    pub throttle: Option<Throttle>,
}

/// Build a ring of `n` handles (index = rank).
pub fn ring(n: usize) -> Vec<RingHandle> {
    assert!(n >= 1);
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // rank r sends to (r+1)%n, so its tx is txs[(r+1)%n]'s producing end;
    // rotate the tx list left by one relative to rx.
    let mut handles = Vec::with_capacity(n);
    let mut txs_rot: Vec<Option<Sender<Wire>>> = txs.into_iter().map(Some).collect();
    for (r, rx) in rxs.into_iter().enumerate() {
        let tx = txs_rot[(r + 1) % n].take().expect("tx taken twice");
        handles.push(RingHandle {
            rank: r,
            n,
            tx_next: tx,
            rx_prev: rx,
            sent_bytes: 0,
            throttle: None,
        });
    }
    handles
}

/// Row-range of ring segment `i` when `rows` are split into `n` segments.
fn seg_range(rows: usize, n: usize, i: usize) -> (usize, usize) {
    // First `rows % n` segments get one extra row.
    let base = rows / n;
    let extra = rows % n;
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    (start, start + len)
}

impl RingHandle {
    /// In-place sum-all-reduce over `data` viewed as `rows × cols`
    /// (row-major). All ranks must call with equal shapes. `quant`
    /// selects the wire format. Returns wire bytes sent by this rank.
    pub fn allreduce(
        &mut self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        quant: CommQuant,
    ) -> u64 {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        if self.n == 1 || data.is_empty() {
            return 0;
        }
        let n = self.n;
        let r = self.rank;
        let before = self.sent_bytes;

        // --- reduce-scatter: after n-1 steps rank r owns segment (r+1)%n.
        for s in 0..n - 1 {
            let send_i = (r + n - s) % n;
            let recv_i = (r + n - s - 1) % n;
            let (a, b) = seg_range(rows, n, send_i);
            self.send_segment(&data[a * cols..b * cols], b - a, cols, quant);
            let (a, b) = seg_range(rows, n, recv_i);
            // accumulate in place — int8 wire dequantizes straight into
            // the accumulator (no intermediate vec, §Perf)
            self.recv_apply(&mut data[a * cols..b * cols], b - a, cols, true);
        }

        // --- all-gather: broadcast the reduced segments around the ring.
        for s in 0..n - 1 {
            let send_i = (r + 1 + n - s) % n;
            let recv_i = (r + n - s) % n;
            let (a, b) = seg_range(rows, n, send_i);
            self.send_segment(&data[a * cols..b * cols], b - a, cols, quant);
            let (a, b) = seg_range(rows, n, recv_i);
            self.recv_apply(&mut data[a * cols..b * cols], b - a, cols, false);
        }
        self.sent_bytes - before
    }

    fn send_segment(&mut self, seg: &[f32], rows: usize, cols: usize, quant: CommQuant) {
        let wire = match quant {
            CommQuant::Int8 => {
                let q = quantize_rows(seg, rows, cols);
                Wire::I8 { rows, cols, scales: q.scales, data: q.data }
            }
            // fp16 wire is modeled as f32 on CPU (same algorithm; the
            // byte accounting for fp16 lives in the simulator).
            CommQuant::Fp16 | CommQuant::F32 => Wire::F32(seg.to_vec()),
        };
        self.sent_bytes += wire.bytes() as u64;
        if let Some(t) = self.throttle {
            t.pace(wire.bytes());
        }
        self.tx_next.send(wire).expect("ring peer hung up");
    }

    /// Receive the next segment and either accumulate (`add = true`,
    /// reduce-scatter) or overwrite (`add = false`, all-gather) in place.
    fn recv_apply(&mut self, out: &mut [f32], rows: usize, cols: usize, add: bool) {
        match self.rx_prev.recv().expect("ring peer hung up") {
            Wire::F32(v) => {
                debug_assert_eq!(v.len(), rows * cols);
                if add {
                    for (o, x) in out.iter_mut().zip(v) {
                        *o += x;
                    }
                } else {
                    out.copy_from_slice(&v);
                }
            }
            Wire::I8 { rows: qr, cols: qc, scales, data } => {
                debug_assert_eq!((qr, qc), (rows, cols));
                let q = crate::quant::QuantizedRows { rows: qr, cols: qc, scales, data };
                if add {
                    crate::quant::dequantize_add(&q, out);
                } else {
                    crate::quant::dequantize_into(&q, out);
                }
            }
        }
    }
}

/// Convenience: run `f(rank, handle)` on `n` scoped threads over a fresh
/// ring and return the per-rank results in rank order.
pub fn run_on_ring<T: Send>(
    n: usize,
    f: impl Fn(usize, &mut RingHandle) -> T + Sync,
) -> Vec<T> {
    let handles = ring(n);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut join = Vec::new();
        for (r, mut h) in handles.into_iter().enumerate() {
            let f = &f;
            join.push(scope.spawn(move || (r, f(r, &mut h))));
        }
        for j in join {
            let (r, v) = j.join().expect("ring worker panicked");
            out[r] = Some(v);
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    fn gold_sum(parts: &[Vec<f32>]) -> Vec<f32> {
        let mut out = vec![0.0f32; parts[0].len()];
        for p in parts {
            for (o, x) in out.iter_mut().zip(p) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn seg_ranges_partition_rows() {
        for rows in [1usize, 5, 16, 17, 64] {
            for n in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                for i in 0..n {
                    let (a, b) = seg_range(rows, n, i);
                    assert_eq!(a, covered, "rows={rows} n={n} i={i}");
                    covered = b;
                }
                assert_eq!(covered, rows);
            }
        }
    }

    #[test]
    fn f32_allreduce_exact() {
        for n in [1usize, 2, 3, 4, 8] {
            let mut rng = Rng::new(100 + n as u64);
            let (rows, cols) = (13, 7); // deliberately not divisible by n
            let parts: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
            let want = gold_sum(&parts);
            let results = run_on_ring(n, |r, h| {
                let mut data = parts[r].clone();
                h.allreduce(&mut data, rows, cols, CommQuant::F32);
                data
            });
            for (r, got) in results.iter().enumerate() {
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "n={n} rank={r} idx={i}: {g} != {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree_bitwise_f32() {
        let n = 4;
        let mut rng = Rng::new(7);
        let parts: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(64, 1.0)).collect();
        let results = run_on_ring(n, |r, h| {
            let mut data = parts[r].clone();
            h.allreduce(&mut data, 8, 8, CommQuant::F32);
            data
        });
        for r in 1..n {
            assert_eq!(results[0], results[r], "rank {r} differs from rank 0");
        }
    }

    #[test]
    fn int8_allreduce_bounded_error() {
        let n = 4;
        let (rows, cols) = (16, 32);
        let mut rng = Rng::new(9);
        let parts: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let want = gold_sum(&parts);
        let results = run_on_ring(n, |r, h| {
            let mut data = parts[r].clone();
            h.allreduce(&mut data, rows, cols, CommQuant::Int8);
            data
        });
        // Error accumulates over ~2(R-1) quantized hops; bound loosely.
        let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let tol = amax * 0.05;
        for got in &results {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= tol, "{g} vs {w} (tol {tol})");
            }
        }
    }

    #[test]
    fn int8_wire_bytes_quarter_of_f32() {
        let n = 4;
        let (rows, cols) = (64, 128);
        let data = vec![1.0f32; rows * cols];
        let bytes = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce(&mut d, rows, cols, CommQuant::F32)
        });
        let bytes_q = run_on_ring(n, |_, h| {
            let mut d = data.clone();
            h.allreduce(&mut d, rows, cols, CommQuant::Int8)
        });
        for (f, q) in bytes.iter().zip(&bytes_q) {
            let ratio = *q as f64 / *f as f64;
            assert!((0.24..0.30).contains(&ratio), "wire ratio {ratio}");
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let mut h = ring(1).pop().unwrap();
        let mut data = vec![1.0, 2.0, 3.0, 4.0];
        let sent = h.allreduce(&mut data, 2, 2, CommQuant::F32);
        assert_eq!(sent, 0);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn back_to_back_allreduces_stay_consistent() {
        // The engine issues two all-reduces per layer; make sure ring
        // state can be reused without cross-talk.
        let n = 3;
        let results = run_on_ring(n, |r, h| {
            let mut a = vec![r as f32; 6];
            h.allreduce(&mut a, 2, 3, CommQuant::F32);
            let mut b = vec![(r + 1) as f32; 6];
            h.allreduce(&mut b, 3, 2, CommQuant::F32);
            (a, b)
        });
        for (a, b) in &results {
            assert!(a.iter().all(|&x| x == 3.0)); // 0+1+2
            assert!(b.iter().all(|&x| x == 6.0)); // 1+2+3
        }
    }

    #[test]
    fn prop_f32_allreduce_matches_gold() {
        Prop::new(41).cases(30).run("ring == serial sum", |rng| {
            let n = rng.range(2, 6);
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 20);
            let parts: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(rows * cols, 2.0)).collect();
            let want = gold_sum(&parts);
            let results = run_on_ring(n, |r, h| {
                let mut d = parts[r].clone();
                h.allreduce(&mut d, rows, cols, CommQuant::F32);
                d
            });
            for got in &results {
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > 1e-3 * w.abs().max(1.0) {
                        return Err(format!("{g} != {w} (n={n} rows={rows} cols={cols})"));
                    }
                }
            }
            Ok(())
        });
    }
}
