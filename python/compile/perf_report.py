"""L1/L2 performance report (DESIGN.md §8).

interpret=True wallclock is NOT a TPU proxy, so the L1 numbers here are
*structural*: VMEM footprint of the flash-attention BlockSpec schedule and
MXU-utilization estimates per configuration, plus an HLO op census of the
lowered stages (catches XLA fusion regressions at L2).

Usage: cd python && python -m compile.perf_report
"""

from __future__ import annotations

import re

import jax

from .kernels.flash_attention import mxu_utilization_estimate, vmem_bytes, _pick_block
from . import aot
from . import model as M


def l1_report() -> None:
    print("=== L1: flash-attention kernel structure ===")
    print(f"{'t':>6} {'S':>7} {'d':>5} {'bq':>5} {'bk':>5} {'VMEM':>10} {'MXU util':>9}")
    for (t, S, d) in [
        (64, 256, 16),        # tiny engine config
        (128, 1024, 128),     # TPU-native tiles
        (512, 8192, 128),     # paper-ish 8k context chunk
        (2048, 65536, 128),   # long-context chunk
    ]:
        bq, bk = _pick_block(t, 128), _pick_block(S, 128)
        vm = vmem_bytes(t, S, d)
        util = mxu_utilization_estimate(t, S, d)
        ok = "" if vm < 16 << 20 else "  !! exceeds 16MiB VMEM"
        print(f"{t:>6} {S:>7} {d:>5} {bq:>5} {bk:>5} {vm/1024:>8.1f}KiB {util:>9.2f}{ok}")


def l2_report() -> None:
    print("\n=== L2: lowered-stage HLO census (fusion check) ===")
    cfg = M.TinyConfig(n_layers=2)
    for name, fn, args in [
        ("attn_tp2_t64", M.make_attn_fn(cfg, 2), None),
        ("mlp_tp2_t64", M.make_mlp_fn(cfg), None),
    ]:
        if name.startswith("attn"):
            hq, hkv = cfg.n_heads // 2, cfg.n_kv_heads // 2
            import jax.numpy as jnp
            sds = jax.ShapeDtypeStruct
            args = (
                sds((64, cfg.d_model), jnp.float32),
                sds((cfg.d_model,), jnp.float32),
                sds((cfg.d_model, hq * cfg.head_dim), jnp.float32),
                sds((cfg.d_model, hkv * cfg.head_dim), jnp.float32),
                sds((cfg.d_model, hkv * cfg.head_dim), jnp.float32),
                sds((hq * cfg.head_dim, cfg.d_model), jnp.float32),
                sds((hkv, cfg.max_seq, cfg.head_dim), jnp.float32),
                sds((hkv, cfg.max_seq, cfg.head_dim), jnp.float32),
                sds((), jnp.int32),
            )
        else:
            import jax.numpy as jnp
            sds = jax.ShapeDtypeStruct
            ff = cfg.d_ff // 2
            args = (
                sds((64, cfg.d_model), jnp.float32),
                sds((cfg.d_model,), jnp.float32),
                sds((cfg.d_model, ff), jnp.float32),
                sds((cfg.d_model, ff), jnp.float32),
                sds((ff, cfg.d_model), jnp.float32),
            )
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        count = lambda op: len(re.findall(rf"\s{op}\(", text))
        dots = count("dot")
        fusions = count("fusion")
        allreduce = count("all-reduce")
        loops = count("while")
        total = len(re.findall(r"^\s+%?\S+ = ", text, re.M))
        print(f"{name}: {total} instructions — dot={dots} fusion={fusions} "
              f"while={loops} all-reduce={allreduce}")
        assert allreduce == 0, "collectives must live in the rust coordinator"
        assert dots >= 3, f"{name}: expected the stage GEMMs to lower to dots"
    print("(no all-reduce in any stage: communication is the rust coordinator's job)")


if __name__ == "__main__":
    l1_report()
    l2_report()
