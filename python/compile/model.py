"""Layer-2: the JAX model — a LLaMA-style GQA transformer, TP-sharded.

The model is expressed as *per-rank stage functions* that exactly mirror
Megatron-style tensor parallelism as the paper describes it (§2.1): each
transformer layer is

    x ─ rmsnorm ─ qkv(shard) ─ rope ─ attention(shard) ─ o_proj(shard) ─▶ partial
        partial ──[ALL-REDUCE (rust collective)]──▶ + residual
    x ─ rmsnorm ─ gate/up(shard) ─ swiglu ─ down(shard) ─▶ partial
        partial ──[ALL-REDUCE (rust collective)]──▶ + residual

The all-reduces and residual adds happen in the rust coordinator — that is
the communication the paper overlaps, so it must live on the rust side
where ISO schedules it. Consequently every stage below computes a *partial*
(pre-all-reduce) result, and the same HLO serves every rank: weights are
runtime inputs, so one artifact per (stage, tp, chunk_len) covers all ranks.

Chunked prefill (and therefore ISO's intra-sequence micro-batches) is
first-class: `attn_chunk_stage` takes the KV cache plus a dynamic sequence
offset, scatters this chunk's K/V into the cache, and attends causally over
absolute positions via the L1 Pallas flash-attention kernel.

Python never runs at serving time; `aot.py` lowers these functions to HLO
text once (`make artifacts`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention_chunk
from .kernels.rmsnorm import rmsnorm
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """Geometry of the tiny-but-real model used for end-to-end validation.

    GQA (n_kv_heads < n_heads) like the paper's 70B config; set
    n_kv_heads == n_heads for the 30B-style MHA variant.
    """

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 16
    d_ff: int = 512
    max_seq: int = 256
    rope_theta: float = 10000.0
    eps: float = 1e-5
    seed: int = 20240817  # arXiv date of the ISO paper

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def validate_tp(self, tp: int) -> None:
        if self.n_heads % tp or self.n_kv_heads % tp or self.d_ff % tp:
            raise ValueError(f"tp={tp} does not divide heads/kv_heads/d_ff of {self}")


MHA_TINY = TinyConfig(n_kv_heads=8)      # MHA variant (paper's 30B analogue)
GQA_TINY = TinyConfig()                  # GQA variant (paper's 70B analogue)


# ---------------------------------------------------------------------------
# Stage functions (per TP rank; weights are runtime inputs)
# ---------------------------------------------------------------------------

def embed_stage(tokens: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
    """tokens [t] int32, emb [vocab, d] → x [t, d] f32 (replicated)."""
    return emb[tokens]


def attn_chunk_stage(
    cfg: TinyConfig,
    tp: int,
    x: jnp.ndarray,        # [t, d] current hidden states (replicated)
    ln_w: jnp.ndarray,     # [d]
    wq: jnp.ndarray,       # [d, q_dim/tp]
    wk: jnp.ndarray,       # [d, kv_dim/tp]
    wv: jnp.ndarray,       # [d, kv_dim/tp]
    wo: jnp.ndarray,       # [q_dim/tp, d]
    k_cache: jnp.ndarray,  # [n_kv_heads/tp, S, head_dim]
    v_cache: jnp.ndarray,  # [n_kv_heads/tp, S, head_dim]
    offset: jnp.ndarray,   # scalar int32 — absolute position of x[0]
    use_pallas: bool = True,
):
    """One rank's attention for one sequence chunk → (partial, k', v').

    `partial` is the pre-all-reduce o_proj output; the rust coordinator
    all-reduces it across ranks and adds the residual.
    """
    t = x.shape[0]
    hq = cfg.n_heads // tp
    hkv = cfg.n_kv_heads // tp
    hd = cfg.head_dim
    positions = offset + jnp.arange(t, dtype=jnp.int32)

    h = rmsnorm(x, ln_w, eps=cfg.eps) if use_pallas else kref.rmsnorm_ref(x, ln_w, cfg.eps)
    q = (h @ wq).reshape(t, hq, hd).transpose(1, 0, 2)    # [hq, t, hd]
    k = (h @ wk).reshape(t, hkv, hd).transpose(1, 0, 2)   # [hkv, t, hd]
    v = (h @ wv).reshape(t, hkv, hd).transpose(1, 0, 2)

    q = kref.rope_ref(q, positions, cfg.rope_theta)
    k = kref.rope_ref(k, positions, cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, offset, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, offset, 0))

    if use_pallas:
        attn = flash_attention_chunk(q, k_cache, v_cache, positions)
    else:
        attn = kref.attention_chunk_ref(q, k_cache, v_cache, positions)
    partial = attn.transpose(1, 0, 2).reshape(t, hq * hd) @ wo   # [t, d]
    return partial, k_cache, v_cache


def mlp_chunk_stage(
    cfg: TinyConfig,
    x: jnp.ndarray,       # [t, d] hidden states after attention all-reduce
    ln_w: jnp.ndarray,    # [d]
    w_gate: jnp.ndarray,  # [d, ff/tp]
    w_up: jnp.ndarray,    # [d, ff/tp]
    w_down: jnp.ndarray,  # [ff/tp, d]
    use_pallas: bool = True,
) -> jnp.ndarray:
    """One rank's MLP for one chunk → partial [t, d] (pre-all-reduce)."""
    h = rmsnorm(x, ln_w, eps=cfg.eps) if use_pallas else kref.rmsnorm_ref(x, ln_w, cfg.eps)
    return kref.swiglu_ref(h, w_gate, w_up, w_down)


def logits_stage(cfg: TinyConfig, x: jnp.ndarray, ln_w: jnp.ndarray,
                 head: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """Final norm + LM head (replicated): x [t, d] → logits [t, vocab]."""
    h = rmsnorm(x, ln_w, eps=cfg.eps) if use_pallas else kref.rmsnorm_ref(x, ln_w, cfg.eps)
    return h @ head


# ---------------------------------------------------------------------------
# Full-model reference (no TP, no chunking) — the numerics oracle
# ---------------------------------------------------------------------------

def forward_reference(cfg: TinyConfig, weights: dict, tokens: jnp.ndarray,
                      use_pallas: bool = False) -> jnp.ndarray:
    """Whole-model single-chunk forward; ground truth for every split.

    Used by pytest to prove (a) TP partial sums == full model and
    (b) chunked prefill == one-shot prefill, and to emit the golden logits
    the rust integration tests assert against.
    """
    t = int(tokens.shape[0])
    x = embed_stage(tokens, weights["emb"])
    offset = jnp.int32(0)
    for layer in range(cfg.n_layers):
        w = weights[f"layer{layer}"]
        k_cache = jnp.zeros((cfg.n_kv_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
        v_cache = jnp.zeros_like(k_cache)
        partial, _, _ = attn_chunk_stage(
            cfg, 1, x, w["ln1"], w["wq"], w["wk"], w["wv"], w["wo"],
            k_cache, v_cache, offset, use_pallas=use_pallas,
        )
        x = x + partial
        x = x + mlp_chunk_stage(cfg, x, w["ln2"], w["w_gate"], w["w_up"],
                                w["w_down"], use_pallas=use_pallas)
    return logits_stage(cfg, x, weights["ln_f"], weights["head"],
                        use_pallas=use_pallas)


def forward_tp_chunked(cfg: TinyConfig, weights: dict, tokens: jnp.ndarray,
                       tp: int, chunk_len: int, use_pallas: bool = True) -> jnp.ndarray:
    """Python emulation of exactly what the rust coordinator executes:
    TP-sharded stages + explicit all-reduce (sum over ranks) + residual,
    chunked prefill with a persistent KV cache per (rank, layer).

    This is the conformance model for the rust engine: same stage
    boundaries, same reduction order, same cache handling.
    """
    from .weights import shard_layer  # local import to avoid cycle

    cfg.validate_tp(tp)
    t_total = int(tokens.shape[0])
    assert t_total % chunk_len == 0
    caches = {
        (r, l): (
            jnp.zeros((cfg.n_kv_heads // tp, cfg.max_seq, cfg.head_dim), jnp.float32),
            jnp.zeros((cfg.n_kv_heads // tp, cfg.max_seq, cfg.head_dim), jnp.float32),
        )
        for r in range(tp) for l in range(cfg.n_layers)
    }
    all_logits = []
    for c in range(t_total // chunk_len):
        offset = jnp.int32(c * chunk_len)
        chunk = tokens[c * chunk_len:(c + 1) * chunk_len]
        x = embed_stage(chunk, weights["emb"])
        for layer in range(cfg.n_layers):
            partials = []
            for r in range(tp):
                sw = shard_layer(cfg, weights[f"layer{layer}"], tp, r)
                kc, vc = caches[(r, layer)]
                p, kc, vc = attn_chunk_stage(
                    cfg, tp, x, sw["ln1"], sw["wq"], sw["wk"], sw["wv"], sw["wo"],
                    kc, vc, offset, use_pallas=use_pallas)
                caches[(r, layer)] = (kc, vc)
                partials.append(p)
            x = x + sum(partials)                       # all-reduce + residual
            partials = []
            for r in range(tp):
                sw = shard_layer(cfg, weights[f"layer{layer}"], tp, r)
                partials.append(mlp_chunk_stage(
                    cfg, x, sw["ln2"], sw["w_gate"], sw["w_up"], sw["w_down"],
                    use_pallas=use_pallas))
            x = x + sum(partials)                       # all-reduce + residual
        all_logits.append(logits_stage(cfg, x, weights["ln_f"], weights["head"],
                                       use_pallas=use_pallas))
    return jnp.concatenate(all_logits, axis=0)


# ---------------------------------------------------------------------------
# Lowering entry points (consumed by aot.py)
# ---------------------------------------------------------------------------

def make_attn_fn(cfg: TinyConfig, tp: int, use_pallas: bool = True):
    """Bind static config → a jit-able attention stage with pure array args."""
    def fn(x, ln_w, wq, wk, wv, wo, k_cache, v_cache, offset):
        return attn_chunk_stage(cfg, tp, x, ln_w, wq, wk, wv, wo,
                                k_cache, v_cache, offset, use_pallas=use_pallas)
    return fn


def make_mlp_fn(cfg: TinyConfig, use_pallas: bool = True):
    def fn(x, ln_w, w_gate, w_up, w_down):
        return (mlp_chunk_stage(cfg, x, ln_w, w_gate, w_up, w_down,
                                use_pallas=use_pallas),)
    return fn


def make_embed_fn():
    def fn(tokens, emb):
        return (embed_stage(tokens, emb),)
    return fn


def make_logits_fn(cfg: TinyConfig, use_pallas: bool = True):
    def fn(x, ln_w, head):
        return (logits_stage(cfg, x, ln_w, head, use_pallas=use_pallas),)
    return fn
