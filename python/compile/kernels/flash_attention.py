"""Layer-1 Pallas kernel: chunked-prefill causal flash attention with GQA.

This is the paper's compute hot spot re-derived for TPU idioms (see
DESIGN.md §3 Hardware adaptation):

* the GPU flash-attention threadblock/shared-memory tiling becomes a
  BlockSpec HBM↔VMEM schedule: grid ``(q_head, q_block, kv_block)`` with the
  innermost kv axis sequential, streaming one ``[block_k, head_dim]`` K/V
  tile into VMEM at a time;
* the warp-level online softmax becomes a vectorized online softmax whose
  running max / denominator / accumulator live in VMEM scratch that
  persists across the sequential kv axis;
* tensor-core WMMA becomes MXU matmuls (``jnp.dot`` with
  ``preferred_element_type=float32``);
* GQA shares K/V tiles across the query-head group via the BlockSpec index
  map (``q_head // group``) — no K/V duplication in VMEM.

Chunked prefill: queries are a chunk of ``t`` tokens at absolute positions
``q_positions`` (``offset .. offset+t-1``); K/V is the max-seq padded cache
that already contains this chunk's keys/values. The causal mask compares
absolute positions, which simultaneously enforces causality *and* masks the
padded tail — exactly the semantics ISO needs for its intra-sequence
micro-batches (chunk 1 attends over chunk 0's cached KV).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; TPU performance is estimated analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    qpos_ref,  # [block_q] int32 — absolute positions of this q tile
    q_ref,     # [1, block_q, head_dim]
    k_ref,     # [1, block_k, head_dim]
    v_ref,     # [1, block_k, head_dim]
    o_ref,     # [1, block_q, head_dim]
    m_scr,     # VMEM [block_q] running max
    l_scr,     # VMEM [block_q] running denominator
    acc_scr,   # VMEM [block_q, head_dim] running numerator
    *,
    sm_scale: float,
    block_k: int,
    kv_blocks: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # [bq, d]
    k = k_ref[0].astype(jnp.float32)          # [bk, d]
    v = v_ref[0].astype(jnp.float32)          # [bk, d]

    # MXU matmul; scores in f32.
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale                               # [bq, bk]

    q_pos = qpos_ref[...]                      # [bq] int32 absolute positions
    k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = k_pos[None, :] <= q_pos[:, None]    # causal over absolute positions
    scores = jnp.where(mask, scores, NEG_INF)

    # Online softmax update.
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)            # rescale factor for old state
    p = jnp.exp(scores - m_new[:, None])
    # Rows where everything is masked so far: m_new == NEG_INF ⇒ p would be
    # exp(0) = 1 for masked entries; force them to zero.
    p = jnp.where(mask, p, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that is ≤ preferred (TPU-native is 128)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_q", "block_k", "interpret")
)
def flash_attention_chunk(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    sm_scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
):
    """Chunked-prefill causal attention (see module docstring).

    Shapes: q ``[n_q_heads, t, d]``; k, v ``[n_kv_heads, S, d]``;
    q_positions ``[t]`` int32. Returns ``[n_q_heads, t, d]`` in q's dtype.
    """
    n_q_heads, t, head_dim = q.shape
    n_kv_heads, S, _ = k.shape
    if n_q_heads % n_kv_heads != 0:
        raise ValueError(f"GQA requires n_q_heads % n_kv_heads == 0, got {q.shape=} {k.shape=}")
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / (head_dim ** 0.5)
    bq = block_q or _pick_block(t, 128)
    bk = block_k or _pick_block(S, 128)
    if t % bq or S % bk:
        raise ValueError(f"block sizes must divide dims: {t=} {bq=} {S=} {bk=}")
    kv_blocks = S // bk

    grid = (n_q_heads, t // bq, kv_blocks)
    kernel = functools.partial(
        _flash_kernel, sm_scale=float(sm_scale), block_k=bk, kv_blocks=kv_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda h, i, j: (i,)),              # q positions
            pl.BlockSpec((1, bq, head_dim), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, head_dim), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, head_dim), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, head_dim), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q_heads, t, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, head_dim), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions.astype(jnp.int32), q, k, v)


def vmem_bytes(t: int, S: int, head_dim: int, block_q: int | None = None,
               block_k: int | None = None, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one kernel instance (DESIGN.md §8)."""
    bq = block_q or _pick_block(t, 128)
    bk = block_k or _pick_block(S, 128)
    tiles = (bq + 2 * bk + bq) * head_dim * dtype_bytes      # q, k, v, o
    scratch = (2 * bq + bq * head_dim) * 4                   # m, l, acc (f32)
    return tiles + scratch + bq * 4                          # + positions


def mxu_utilization_estimate(t: int, S: int, head_dim: int) -> float:
    """Fraction of MXU-issue slots doing useful work for one (q,kv) tile pair.

    The MXU is a 128×128 systolic array; a [bq,d]×[d,bk] matmul keeps it
    busy for ceil(bq/128)*ceil(bk/128)*ceil(d/128) passes of which the
    useful fraction is (bq*bk*d) / (ceil…*128^3).
    """
    import math

    bq = _pick_block(t, 128)
    bk = _pick_block(S, 128)
    passes = math.ceil(bq / 128) * math.ceil(bk / 128) * math.ceil(head_dim / 128)
    return (bq * bk * head_dim) / (passes * 128 ** 3)
