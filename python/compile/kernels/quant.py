"""Layer-1 Pallas kernels: symmetric per-row int8 quantize / dequantize.

These implement the paper's §3.2 "communication dominates" optimization —
the 4090 wire format converts fp16/fp32 activations to int8 before the
tensor-parallel all-reduce, halving (vs fp16) or quartering (vs fp32) the
bytes on the ring. The rust collective (`rust/src/quant.rs`) implements the
identical algorithm on the wire; these kernels are the in-graph variant and
the cross-language conformance oracle.

TPU notes: per-row amax is a lane reduction (VPU), the scale broadcast and
round are elementwise; rows are tiled in VMEM-sized row blocks. Stored
scales are f32; payload int8 (int8 is also the MXU's high-rate input type,
which is why the paper quantizes weights/KV to int8 in the first place).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # [br, d]
    amax = jnp.max(jnp.abs(x), axis=-1)                   # [br]
    scale = amax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv[:, None]), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]


def _pick_block(n: int, preferred: int = 128) -> int:
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8(x: jnp.ndarray, block_rows: int | None = None, interpret: bool = True):
    """Quantize ``x: [n, d]`` → (q int8 ``[n, d]``, scale f32 ``[n]``)."""
    n, d = x.shape
    br = block_rows or _pick_block(n)
    grid = (n // br,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, block_rows: int | None = None,
                    interpret: bool = True):
    """Dequantize (q int8 ``[n, d]``, scale ``[n]``) → f32 ``[n, d]``."""
    n, d = q.shape
    br = block_rows or _pick_block(n)
    grid = (n // br,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, scale)
