"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the Pallas kernels match these within dtype-appropriate tolerances.
They are also what the L2 model traces when ``use_pallas=False``.
"""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x / rms(x) * weight."""
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jnp.reciprocal(jnp.sqrt(var + eps)) * weight.astype(jnp.float32)
    return out.astype(orig_dtype)


def quantize_int8_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization over the last axis.

    Returns (q, scale) with q int8 in [-127, 127] and scale float32 such
    that ``x ≈ q * scale`` row-wise. Zero rows get scale 0 (and q == 0),
    matching the rust `quant::quantize_rows` implementation bit-for-bit in
    round-to-nearest-even.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xf * inv), -127.0, 127.0).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_int8_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8_ref` (lossy)."""
    return q.astype(jnp.float32) * scale[..., None]


def attention_chunk_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Chunked-prefill causal attention over a (padded) KV cache.

    Args:
      q: ``[n_q_heads, t, head_dim]`` — queries for the current chunk.
      k, v: ``[n_kv_heads, S, head_dim]`` — the full (max-seq padded) cache,
        already containing this chunk's keys/values at their absolute
        positions. ``n_q_heads % n_kv_heads == 0`` (GQA; MHA when equal).
      q_positions: ``[t]`` int32 absolute positions of the chunk's queries.
      sm_scale: softmax scale; defaults to ``1/sqrt(head_dim)``.

    The causal mask compares *absolute* positions: key position ``j`` is
    visible to query position ``p`` iff ``j <= p``. Padding beyond the
    valid prefix is masked out automatically because every padded position
    exceeds the largest query position.
    """
    n_q_heads, t, head_dim = q.shape
    n_kv_heads, S, _ = k.shape
    assert n_q_heads % n_kv_heads == 0
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / (head_dim ** 0.5)

    k_pos = jnp.arange(S, dtype=jnp.int32)
    mask = k_pos[None, :] <= q_positions.astype(jnp.int32)[:, None]  # [t, S]

    kq = jnp.repeat(k, group, axis=0)  # [n_q_heads, S, d]
    vq = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum(
        "htd,hsd->hts",
        q.astype(jnp.float32),
        kq.astype(jnp.float32),
    ) * sm_scale
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("hts,hsd->htd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    """LLaMA-style SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    h = (g * jnp.reciprocal(1.0 + jnp.exp(-g))) * u
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def rope_ref(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary position embedding (half-split convention).

    x: ``[n_heads, t, head_dim]``; positions: ``[t]`` absolute positions.
    """
    n_heads, t, head_dim = x.shape
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [t, half]
    cos = jnp.cos(angles)[None, :, :]
    sin = jnp.sin(angles)[None, :, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
