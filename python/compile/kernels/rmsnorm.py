"""Layer-1 Pallas kernel: fused RMSNorm.

Fuses the variance reduction, rsqrt, and weight multiply into one VMEM pass
(the unfused jnp version reads x three times from HBM). Row-blocked grid;
accumulation in f32 regardless of input dtype, matching `ref.rmsnorm_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # [br, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def _pick_block(n: int, preferred: int = 128) -> int:
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5,
            block_rows: int | None = None, interpret: bool = True):
    """RMSNorm ``x: [n, d]`` with weight ``[d]`` → ``[n, d]`` in x's dtype."""
    n, d = x.shape
    br = block_rows or _pick_block(n)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=float(eps)),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, weight)
