"""AOT lowering: JAX stages → HLO text artifacts + manifest for rust.

Run once at build time (`make artifacts`); python is never on the request
path. The interchange format is **HLO text**, not a serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids which
the `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under artifacts/:
  <stage>_tp<R>_t<T>.hlo.txt   one per (stage, tp-degree, chunk length);
                               rank-agnostic (weights are runtime inputs)
  weights_tp<R>/<tensor>.f32   raw little-endian f32 shard dumps
  golden_tokens.i32 / golden_logits.f32
                               reference prompt + full-model logits the
                               rust integration tests assert against
  manifest.json                index of all of the above + model geometry
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import weights as W

# Chunk lengths compiled for the engine: 1 is the decode step; the rest are
# prefill chunk sizes ISO picks from when splitting a sequence.
CHUNK_LENS = (1, 16, 32, 64)
TP_DEGREES = (1, 2, 4)
GOLDEN_PROMPT_LEN = 96


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_stage(name, fn, example_args, out_dir, inputs_meta, outputs_meta, **meta):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    entry = {"name": name, "file": f"{name}.hlo.txt",
             "inputs": inputs_meta, "outputs": outputs_meta}
    entry.update(meta)
    return entry


def build_all(out_dir: str, cfg: M.TinyConfig, use_pallas: bool = True,
              chunk_lens=CHUNK_LENS, tp_degrees=TP_DEGREES) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    d, hd, S, V = cfg.d_model, cfg.head_dim, cfg.max_seq, cfg.vocab
    modules = []

    # --- embed & logits (replicated; depend only on t) -----------------
    for t in chunk_lens:
        modules.append(lower_stage(
            f"embed_t{t}", M.make_embed_fn(),
            (_sds((t,), jnp.int32), _sds((V, d))), out_dir,
            [_spec((t,), "i32"), _spec((V, d))], [_spec((t, d))],
            stage="embed", tp=0, t=t))
        modules.append(lower_stage(
            f"logits_t{t}", M.make_logits_fn(cfg, use_pallas),
            (_sds((t, d)), _sds((d,)), _sds((d, V))), out_dir,
            [_spec((t, d)), _spec((d,)), _spec((d, V))], [_spec((t, V))],
            stage="logits", tp=0, t=t))

    # --- attention & MLP (per tp degree × chunk length) -----------------
    for tp in tp_degrees:
        cfg.validate_tp(tp)
        hq, hkv, ff = cfg.n_heads // tp, cfg.n_kv_heads // tp, cfg.d_ff // tp
        for t in chunk_lens:
            attn_args = (
                _sds((t, d)), _sds((d,)),
                _sds((d, hq * hd)), _sds((d, hkv * hd)), _sds((d, hkv * hd)),
                _sds((hq * hd, d)),
                _sds((hkv, S, hd)), _sds((hkv, S, hd)),
                _sds((), jnp.int32),
            )
            modules.append(lower_stage(
                f"attn_tp{tp}_t{t}", M.make_attn_fn(cfg, tp, use_pallas),
                attn_args, out_dir,
                [_spec((t, d)), _spec((d,)), _spec((d, hq * hd)),
                 _spec((d, hkv * hd)), _spec((d, hkv * hd)), _spec((hq * hd, d)),
                 _spec((hkv, S, hd)), _spec((hkv, S, hd)), _spec((), "i32")],
                [_spec((t, d)), _spec((hkv, S, hd)), _spec((hkv, S, hd))],
                stage="attn", tp=tp, t=t))
            modules.append(lower_stage(
                f"mlp_tp{tp}_t{t}", M.make_mlp_fn(cfg, use_pallas),
                (_sds((t, d)), _sds((d,)), _sds((d, ff)), _sds((d, ff)), _sds((ff, d))),
                out_dir,
                [_spec((t, d)), _spec((d,)), _spec((d, ff)), _spec((d, ff)),
                 _spec((ff, d))],
                [_spec((t, d))],
                stage="mlp", tp=tp, t=t))

    # --- weights (sharded per tp degree) --------------------------------
    weights = W.make_weights(cfg)
    weight_entries = {}
    for tp in tp_degrees:
        wdir = os.path.join(out_dir, f"weights_tp{tp}")
        weight_entries[f"tp{tp}"] = W.export_weights(cfg, weights, tp, wdir)

    # --- golden reference (full model, no TP, no chunking) --------------
    rng = np.random.default_rng(cfg.seed)
    tokens = rng.integers(0, V, size=GOLDEN_PROMPT_LEN, dtype=np.int32)
    logits = np.asarray(
        M.forward_reference(cfg, weights, jnp.asarray(tokens), use_pallas=False),
        dtype=np.float32)
    tokens.tofile(os.path.join(out_dir, "golden_tokens.i32"))
    logits.tofile(os.path.join(out_dir, "golden_logits.f32"))

    manifest = {
        "format_version": 1,
        "paper": "ISO: Overlap of Computation and Communication within Sequence (Xiao & Su, 2024)",
        "config": {
            "vocab": V, "d_model": d, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
            "head_dim": hd, "d_ff": cfg.d_ff, "max_seq": S,
            "eps": cfg.eps, "rope_theta": cfg.rope_theta, "seed": cfg.seed,
        },
        "chunk_lens": list(chunk_lens),
        "tp_degrees": list(tp_degrees),
        "modules": modules,
        "weights": weight_entries,
        "golden": {
            "tokens_file": "golden_tokens.i32",
            "logits_file": "golden_logits.f32",
            "prompt_len": GOLDEN_PROMPT_LEN,
            "logits_shape": [GOLDEN_PROMPT_LEN, V],
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of Pallas kernels")
    args = ap.parse_args()
    manifest = build_all(args.out, M.GQA_TINY, use_pallas=not args.no_pallas)
    n = len(manifest["modules"])
    print(f"wrote {n} HLO modules + weights + golden to {args.out}")


if __name__ == "__main__":
    main()
