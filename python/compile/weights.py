"""Deterministic tiny-model weights + Megatron-style TP sharding.

The paper's checkpoints are proprietary; per DESIGN.md §2 we substitute a
deterministic synthetic model: weights are drawn from a fixed PRNG seed so
python tests, the AOT artifacts, and the rust engine all agree bit-for-bit
on what the model is. `export_weights` dumps every *sharded* tensor as raw
little-endian f32 (plus a manifest entry) for the rust runtime to mmap.

Sharding follows Megatron-LM exactly (the paper's §2.1 TP layout):
  column-parallel: wq, wk, wv (split output dim, by head), w_gate, w_up;
  row-parallel:    wo, w_down (split input dim) → partial sums that the
                   rust collective all-reduces.
"""

from __future__ import annotations

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np

from .model import TinyConfig


def _init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def make_weights(cfg: TinyConfig) -> dict:
    """Full (unsharded) weights, deterministic in cfg.seed."""
    key = jax.random.PRNGKey(cfg.seed)
    n_keys = 2 + cfg.n_layers * 9 + 2
    keys = iter(jax.random.split(key, n_keys))
    w = {
        "emb": _init(next(keys), (cfg.vocab, cfg.d_model), scale=0.02),
        "head": _init(next(keys), (cfg.d_model, cfg.vocab)),
    }
    for layer in range(cfg.n_layers):
        w[f"layer{layer}"] = {
            "ln1": 1.0 + 0.01 * _init(next(keys), (cfg.d_model,), scale=1.0),
            "wq": _init(next(keys), (cfg.d_model, cfg.q_dim)),
            "wk": _init(next(keys), (cfg.d_model, cfg.kv_dim)),
            "wv": _init(next(keys), (cfg.d_model, cfg.kv_dim)),
            "wo": _init(next(keys), (cfg.q_dim, cfg.d_model)),
            "ln2": 1.0 + 0.01 * _init(next(keys), (cfg.d_model,), scale=1.0),
            "w_gate": _init(next(keys), (cfg.d_model, cfg.d_ff)),
            "w_up": _init(next(keys), (cfg.d_model, cfg.d_ff)),
            "w_down": _init(next(keys), (cfg.d_ff, cfg.d_model)),
        }
    w["ln_f"] = 1.0 + 0.01 * _init(next(keys), (cfg.d_model,), scale=1.0)
    return w


def shard_layer(cfg: TinyConfig, lw: dict, tp: int, rank: int) -> dict:
    """Megatron TP shard of one layer's weights for `rank` of `tp`."""
    cfg.validate_tp(tp)
    hq, hkv, ff = cfg.n_heads // tp, cfg.n_kv_heads // tp, cfg.d_ff // tp
    hd = cfg.head_dim

    def col_heads(wm, heads_per_rank):  # [d, H*hd] → rank's [d, hpr*hd]
        return wm[:, rank * heads_per_rank * hd:(rank + 1) * heads_per_rank * hd]

    return {
        "ln1": lw["ln1"],
        "wq": col_heads(lw["wq"], hq),
        "wk": col_heads(lw["wk"], hkv),
        "wv": col_heads(lw["wv"], hkv),
        "wo": lw["wo"][rank * hq * hd:(rank + 1) * hq * hd, :],
        "ln2": lw["ln2"],
        "w_gate": lw["w_gate"][:, rank * ff:(rank + 1) * ff],
        "w_up": lw["w_up"][:, rank * ff:(rank + 1) * ff],
        "w_down": lw["w_down"][rank * ff:(rank + 1) * ff, :],
    }


def export_weights(cfg: TinyConfig, weights: dict, tp: int, out_dir: str) -> list[dict]:
    """Dump per-rank sharded tensors as raw LE f32; return manifest entries."""
    os.makedirs(out_dir, exist_ok=True)
    entries: list[dict] = []

    def dump(name: str, arr) -> None:
        a = np.asarray(arr, dtype=np.float32)
        path = os.path.join(out_dir, f"{name}.f32")
        a.tofile(path)
        entries.append({"name": name, "shape": list(a.shape),
                        "dtype": "f32", "file": f"{os.path.basename(out_dir)}/{name}.f32"})

    dump("emb", weights["emb"])
    dump("head", weights["head"])
    dump("ln_f", weights["ln_f"])
    for layer in range(cfg.n_layers):
        for r in range(tp):
            sw = shard_layer(cfg, weights[f"layer{layer}"], tp, r)
            for tname, arr in sw.items():
                dump(f"layer{layer}.rank{r}.{tname}", arr)
    return entries
