"""L2 correctness: stage functions, TP sharding, chunked-prefill invariants.

The two theorems the whole system rests on (paper §3.1):
  1. TP partial sums == full model (Megatron sharding is exact);
  2. chunked prefill over a persistent KV cache == one-shot prefill —
     therefore ISO's intra-sequence split is *numerically free*.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import weights as W

CFG = M.TinyConfig(n_layers=2)  # 2 layers keep the test fast; geometry identical
FULL = M.GQA_TINY


@pytest.fixture(scope="module")
def weights():
    return W.make_weights(CFG)


def tokens(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=n, dtype=np.int32))


class TestShapes:
    def test_embed(self, weights):
        x = M.embed_stage(tokens(8), weights["emb"])
        assert x.shape == (8, CFG.d_model)

    @pytest.mark.parametrize("tp", [1, 2, 4])
    def test_attn_stage_shapes(self, weights, tp):
        sw = W.shard_layer(CFG, weights["layer0"], tp, 0)
        x = M.embed_stage(tokens(16), weights["emb"])
        kc = jnp.zeros((CFG.n_kv_heads // tp, CFG.max_seq, CFG.head_dim), jnp.float32)
        p, k2, v2 = M.attn_chunk_stage(
            CFG, tp, x, sw["ln1"], sw["wq"], sw["wk"], sw["wv"], sw["wo"],
            kc, kc, jnp.int32(0))
        assert p.shape == (16, CFG.d_model)
        assert k2.shape == kc.shape and v2.shape == kc.shape

    @pytest.mark.parametrize("tp", [1, 2, 4])
    def test_mlp_stage_shapes(self, weights, tp):
        sw = W.shard_layer(CFG, weights["layer0"], tp, 0)
        x = M.embed_stage(tokens(16), weights["emb"])
        p = M.mlp_chunk_stage(CFG, x, sw["ln2"], sw["w_gate"], sw["w_up"], sw["w_down"])
        assert p.shape == (16, CFG.d_model)

    def test_logits(self, weights):
        x = M.embed_stage(tokens(4), weights["emb"])
        lg = M.logits_stage(CFG, x, weights["ln_f"], weights["head"])
        assert lg.shape == (4, CFG.vocab)


class TestTpExactness:
    """Sum over rank partials must equal the unsharded computation."""

    @pytest.mark.parametrize("tp", [2, 4])
    def test_attn_partials_sum_to_full(self, weights, tp):
        toks = tokens(16, seed=1)
        x = M.embed_stage(toks, weights["emb"])
        lw = weights["layer0"]
        S = CFG.max_seq

        kc_full = jnp.zeros((CFG.n_kv_heads, S, CFG.head_dim), jnp.float32)
        full, _, _ = M.attn_chunk_stage(
            CFG, 1, x, lw["ln1"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
            kc_full, kc_full, jnp.int32(0), use_pallas=False)

        acc = jnp.zeros_like(full)
        for r in range(tp):
            sw = W.shard_layer(CFG, lw, tp, r)
            kc = jnp.zeros((CFG.n_kv_heads // tp, S, CFG.head_dim), jnp.float32)
            p, _, _ = M.attn_chunk_stage(
                CFG, tp, x, sw["ln1"], sw["wq"], sw["wk"], sw["wv"], sw["wo"],
                kc, kc, jnp.int32(0), use_pallas=False)
            acc = acc + p
        np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("tp", [2, 4])
    def test_mlp_partials_sum_to_full(self, weights, tp):
        x = M.embed_stage(tokens(16, seed=2), weights["emb"])
        lw = weights["layer0"]
        full = M.mlp_chunk_stage(CFG, x, lw["ln2"], lw["w_gate"], lw["w_up"],
                                 lw["w_down"], use_pallas=False)
        acc = jnp.zeros_like(full)
        for r in range(tp):
            sw = W.shard_layer(CFG, lw, tp, r)
            acc = acc + M.mlp_chunk_stage(CFG, x, sw["ln2"], sw["w_gate"],
                                          sw["w_up"], sw["w_down"], use_pallas=False)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)


class TestChunkedPrefill:
    """ISO's enabling invariant: chunked == one-shot (paper §3.1)."""

    @pytest.mark.parametrize("tp,chunk", [(1, 16), (1, 32), (2, 16), (2, 32), (4, 16)])
    def test_chunked_tp_equals_reference(self, weights, tp, chunk):
        toks = tokens(64, seed=3)
        ref_logits = M.forward_reference(CFG, weights, toks, use_pallas=False)
        got = M.forward_tp_chunked(CFG, weights, toks, tp=tp, chunk_len=chunk,
                                   use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                                   rtol=5e-4, atol=5e-4)

    def test_pallas_path_matches_ref_path(self, weights):
        toks = tokens(32, seed=4)
        a = M.forward_tp_chunked(CFG, weights, toks, tp=2, chunk_len=16,
                                 use_pallas=True)
        b = M.forward_tp_chunked(CFG, weights, toks, tp=2, chunk_len=16,
                                 use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4)

    def test_uneven_iso_split_equals_even(self, weights):
        """Paper §6: a 60/40 split must be as exact as 50/50 — the split
        point is a pure scheduling knob, never a numerics knob."""
        toks = tokens(64, seed=5)
        even = M.forward_tp_chunked(CFG, weights, toks, tp=2, chunk_len=32,
                                    use_pallas=False)
        uneven = M.forward_tp_chunked(CFG, weights, toks, tp=2, chunk_len=16,
                                      use_pallas=False)  # 4 chunks of 16
        np.testing.assert_allclose(np.asarray(even), np.asarray(uneven),
                                   rtol=5e-4, atol=5e-4)


class TestWeights:
    def test_deterministic(self):
        a = W.make_weights(CFG)
        b = W.make_weights(CFG)
        np.testing.assert_array_equal(np.asarray(a["emb"]), np.asarray(b["emb"]))
        np.testing.assert_array_equal(np.asarray(a["layer0"]["wq"]),
                                      np.asarray(b["layer0"]["wq"]))

    def test_shards_partition_columns(self, weights):
        lw = weights["layer0"]
        parts = [W.shard_layer(CFG, lw, 2, r)["wq"] for r in range(2)]
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(parts, axis=1)), np.asarray(lw["wq"]))

    def test_shards_partition_rows(self, weights):
        lw = weights["layer0"]
        parts = [W.shard_layer(CFG, lw, 4, r)["w_down"] for r in range(4)]
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(parts, axis=0)), np.asarray(lw["w_down"]))

    def test_invalid_tp_rejected(self):
        with pytest.raises(ValueError):
            CFG.validate_tp(3)

    def test_export_manifest_entries(self, weights, tmp_path):
        entries = W.export_weights(CFG, weights, 2, str(tmp_path / "w"))
        names = {e["name"] for e in entries}
        assert "emb" in names and "layer0.rank0.wq" in names
        assert "layer1.rank1.w_down" in names
        # file sizes match shapes
        for e in entries:
            sz = (tmp_path / "w" / (e["name"] + ".f32")).stat().st_size
            want = 4 * int(np.prod(e["shape"]))
            assert sz == want
