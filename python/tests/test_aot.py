"""AOT pipeline: lowering produces parseable HLO text + a sound manifest.

Also guards the interchange contract with the rust runtime: HLO *text*
(xla_extension 0.5.1 rejects jax≥0.5 64-bit-id protos), tuple returns,
and entry-parameter ordering matching the manifest input specs.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import weights as W

CFG = M.TinyConfig(n_layers=2)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_all(out, CFG, use_pallas=True,
                             chunk_lens=(1, 16), tp_degrees=(1, 2))
    return out, manifest


class TestHloText:
    def test_modules_written_and_nonempty(self, built):
        out, manifest = built
        assert len(manifest["modules"]) == 2 * 2 + 2 * 2 * 2  # embed+logits, attn+mlp
        for m in manifest["modules"]:
            path = os.path.join(out, m["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), m["name"]
            assert "ENTRY" in text

    def test_text_reparses_via_hlo_parser(self, built):
        """Round-trip every artifact through XLA's HLO-text parser — the
        exact parser the rust runtime uses (`HloModuleProto::from_text_file`).
        Numeric execution of the artifacts is validated on the rust side
        (rust/tests/runtime_integration.rs) where the real consumer lives."""
        out, manifest = built
        from jax._src.lib import xla_client as xc
        for m in manifest["modules"]:
            text = open(os.path.join(out, m["file"])).read()
            module = xc._xla.hlo_module_from_text(text)
            proto = module.as_serialized_hlo_module_proto()
            assert len(proto) > 0, m["name"]

    def test_entry_parameter_count_matches_manifest(self, built):
        """The rust runtime feeds literals positionally; the HLO ENTRY
        signature must have exactly one parameter per manifest input."""
        out, manifest = built
        import re
        for m in manifest["modules"]:
            text = open(os.path.join(out, m["file"])).read()
            # The ENTRY computation is the last block; its parameters appear
            # as "... = <type> parameter(N)" instructions.
            entry_start = text.rindex("ENTRY ")
            entry = text[entry_start:]
            indices = {int(i) for i in re.findall(r"\bparameter\((\d+)\)", entry)}
            assert indices == set(range(len(m["inputs"]))), (m["name"], sorted(indices))


class TestManifest:
    def test_config_round_trips(self, built):
        out, _ = built
        m = json.load(open(os.path.join(out, "manifest.json")))
        c = m["config"]
        assert c["d_model"] == CFG.d_model
        assert c["n_kv_heads"] == CFG.n_kv_heads
        assert m["format_version"] == 1

    def test_every_weight_file_exists_with_right_size(self, built):
        out, manifest = built
        for tp_key, entries in manifest["weights"].items():
            for e in entries:
                path = os.path.join(out, e["file"])
                assert os.path.exists(path), e
                assert os.path.getsize(path) == 4 * int(np.prod(e["shape"]))

    def test_golden_matches_fresh_reference(self, built):
        out, manifest = built
        g = manifest["golden"]
        toks = np.fromfile(os.path.join(out, g["tokens_file"]), np.int32)
        logits = np.fromfile(os.path.join(out, g["logits_file"]), np.float32)
        logits = logits.reshape(g["logits_shape"])
        assert toks.shape[0] == g["prompt_len"]
        weights = W.make_weights(CFG)
        expect = M.forward_reference(CFG, weights, jnp.asarray(toks), use_pallas=False)
        np.testing.assert_allclose(logits, np.asarray(expect), rtol=1e-5, atol=1e-5)

    def test_module_inventory_covers_grid(self, built):
        _, manifest = built
        names = {m["name"] for m in manifest["modules"]}
        for tp in (1, 2):
            for t in (1, 16):
                assert f"attn_tp{tp}_t{t}" in names
                assert f"mlp_tp{tp}_t{t}" in names
        for t in (1, 16):
            assert f"embed_t{t}" in names and f"logits_t{t}" in names
