"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/offsets; `assert_allclose` against ref.py.
This is the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import (
    flash_attention_chunk,
    mxu_utilization_estimate,
    vmem_bytes,
    _pick_block,
)
from compile.kernels.quant import dequantize_int8, quantize_int8
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    def test_matches_ref_gqa_mha(self, hq, hkv, dtype):
        t, S, d = 16, 64, 32
        q = rand(0, (hq, t, d), dtype)
        k = rand(1, (hkv, S, d), dtype)
        v = rand(2, (hkv, S, d), dtype)
        pos = jnp.arange(8, 8 + t, dtype=jnp.int32)
        out = flash_attention_chunk(q, k, v, pos)
        expect = ref.attention_chunk_ref(q, k, v, pos)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol(dtype))

    def test_chunk_at_offset_zero(self):
        q = rand(3, (2, 8, 16))
        k = rand(4, (2, 32, 16))
        v = rand(5, (2, 32, 16))
        pos = jnp.arange(8, dtype=jnp.int32)
        np.testing.assert_allclose(
            flash_attention_chunk(q, k, v, pos),
            ref.attention_chunk_ref(q, k, v, pos), rtol=3e-5, atol=3e-5)

    def test_single_token_decode_shape(self):
        # t=1 is the decode step the engine reuses this kernel for.
        q = rand(6, (4, 1, 16))
        k = rand(7, (2, 64, 16))
        v = rand(8, (2, 64, 16))
        pos = jnp.asarray([37], jnp.int32)
        out = flash_attention_chunk(q, k, v, pos)
        assert out.shape == (4, 1, 16)
        np.testing.assert_allclose(
            out, ref.attention_chunk_ref(q, k, v, pos), rtol=3e-5, atol=3e-5)

    def test_causality_future_keys_ignored(self):
        """Keys strictly after the query positions must not affect output."""
        t, S = 8, 64
        q = rand(9, (2, t, 16))
        k = rand(10, (2, S, 16))
        v = rand(11, (2, S, 16))
        pos = jnp.arange(t, dtype=jnp.int32)  # offset 0 → only first t keys visible
        base = flash_attention_chunk(q, k, v, pos)
        k2 = k.at[:, t:, :].set(999.0)
        v2 = v.at[:, t:, :].set(-999.0)
        np.testing.assert_allclose(base, flash_attention_chunk(q, k2, v2, pos),
                                   rtol=1e-6, atol=1e-6)

    def test_block_sizes_do_not_change_result(self):
        q = rand(12, (2, 32, 16))
        k = rand(13, (2, 128, 16))
        v = rand(14, (2, 128, 16))
        pos = jnp.arange(64, 96, dtype=jnp.int32)
        a = flash_attention_chunk(q, k, v, pos, block_q=8, block_k=16)
        b = flash_attention_chunk(q, k, v, pos, block_q=32, block_k=128)
        np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        hkv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        t=st.sampled_from([1, 4, 8, 16]),
        s_blocks=st.integers(1, 4),
        off_frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, hkv, group, t, s_blocks, off_frac, seed):
        d = 16
        S = 32 * s_blocks
        off = int(off_frac * (S - t))
        hq = hkv * group
        q = rand(seed, (hq, t, d))
        k = rand(seed + 1, (hkv, S, d))
        v = rand(seed + 2, (hkv, S, d))
        pos = jnp.arange(off, off + t, dtype=jnp.int32)
        np.testing.assert_allclose(
            flash_attention_chunk(q, k, v, pos),
            ref.attention_chunk_ref(q, k, v, pos), rtol=5e-5, atol=5e-5)

    def test_two_chunks_equal_one_shot(self):
        """The ISO invariant: splitting a sequence into two chunks (second
        attending over the first's cached KV) gives identical attention."""
        hq, hkv, d, S = 4, 2, 16, 64
        full_t = 32
        half = full_t // 2
        q = rand(20, (hq, full_t, d))
        k = rand(21, (hkv, S, d))
        v = rand(22, (hkv, S, d))
        pos = jnp.arange(full_t, dtype=jnp.int32)
        one = flash_attention_chunk(q, k, v, pos)
        c0 = flash_attention_chunk(q[:, :half], k, v, pos[:half])
        c1 = flash_attention_chunk(q[:, half:], k, v, pos[half:])
        np.testing.assert_allclose(one, jnp.concatenate([c0, c1], axis=1),
                                   rtol=2e-6, atol=2e-6)

    def test_rejects_bad_gqa(self):
        with pytest.raises(ValueError):
            flash_attention_chunk(rand(0, (3, 8, 16)), rand(1, (2, 32, 16)),
                                  rand(2, (2, 32, 16)), jnp.arange(8, dtype=jnp.int32))

    def test_vmem_estimate_positive_and_monotone(self):
        small = vmem_bytes(16, 64, 32)
        big = vmem_bytes(128, 1024, 128)
        assert 0 < small < big
        assert big < 16 * 1024 * 1024  # fits TPU VMEM

    def test_mxu_utilization_bounds(self):
        for t, S, d in [(128, 1024, 128), (16, 64, 32), (1, 256, 16)]:
            u = mxu_utilization_estimate(t, S, d)
            assert 0.0 < u <= 1.0
        assert mxu_utilization_estimate(128, 1024, 128) == 1.0

    def test_pick_block_divides(self):
        for n in [1, 2, 6, 96, 128, 130, 256]:
            b = _pick_block(n, 128)
            assert n % b == 0 and 1 <= b <= min(n, 128)


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

class TestQuant:
    @pytest.mark.parametrize("n,d", [(1, 8), (16, 64), (128, 128), (3, 256)])
    def test_matches_ref(self, n, d):
        x = rand(30 + n, (n, d), scale=3.0)
        q, s = quantize_int8(x)
        qr, sr = ref.quantize_int8_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_roundtrip_error_bound(self):
        """|x - dq(q(x))| <= scale/2 per element (symmetric quant bound)."""
        x = rand(40, (32, 128), scale=5.0)
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        err = np.abs(np.asarray(x) - np.asarray(back))
        bound = np.asarray(s)[:, None] * 0.5 + 1e-7
        assert (err <= bound).all()

    def test_zero_rows(self):
        x = jnp.zeros((4, 32), jnp.float32)
        q, s = quantize_int8(x)
        assert np.all(np.asarray(q) == 0) and np.all(np.asarray(s) == 0.0)
        np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)), 0.0)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 64), d=st.sampled_from([8, 32, 128]),
           scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
    def test_hypothesis_roundtrip(self, n, d, scale, seed):
        x = rand(seed, (n, d), scale=scale)
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        err = np.abs(np.asarray(x) - np.asarray(back))
        assert (err <= np.asarray(s)[:, None] * 0.5 + 1e-6 * scale).all()

    def test_relative_error_well_conditioned(self):
        """Paper §3.2 relies on int8 comm being ~lossless for activations.

        Symmetric per-row int8 on gaussian rows gives relative RMS error
        ≈ (amax/127)/(sqrt(12)·σ) ≈ 0.8% — assert we're in that regime.
        """
        x = rand(50, (64, 256), scale=2.0)
        q, s = quantize_int8(x)
        back = np.asarray(dequantize_int8(q, s))
        rel = np.linalg.norm(back - np.asarray(x)) / np.linalg.norm(np.asarray(x))
        assert rel < 1.2e-2


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

class TestRmsnorm:
    @pytest.mark.parametrize("n,d", [(1, 16), (8, 128), (64, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, dtype):
        x = rand(60 + n, (n, d), dtype)
        w = rand(61 + n, (d,))
        np.testing.assert_allclose(
            np.asarray(rmsnorm(x, w), np.float32),
            np.asarray(ref.rmsnorm_ref(x, w), np.float32), **tol(dtype))

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 32), d=st.sampled_from([16, 64, 128]),
           seed=st.integers(0, 2**16))
    def test_hypothesis(self, n, d, seed):
        x = rand(seed, (n, d), scale=4.0)
        w = rand(seed + 9, (d,))
        np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm_ref(x, w),
                                   rtol=4e-5, atol=4e-5)

    def test_scale_invariance(self):
        """rmsnorm(c*x) == rmsnorm(x) up to eps effects."""
        x = rand(70, (4, 64), scale=1.0)
        w = jnp.ones((64,), jnp.float32)
        a = np.asarray(rmsnorm(x, w))
        b = np.asarray(rmsnorm(x * 1000.0, w))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
