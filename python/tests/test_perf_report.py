"""L1/L2 structural performance contracts (DESIGN.md §8).

These encode the §Perf targets as tests: the flash-attention BlockSpec
schedule must fit VMEM at every supported config, hit full MXU utilization
at TPU-native tiles, and the lowered stages must contain no collectives
(communication belongs to the rust coordinator).
"""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.kernels.flash_attention import (
    mxu_utilization_estimate,
    vmem_bytes,
)

VMEM_LIMIT = 16 << 20  # 16 MiB per TensorCore


class TestL1Structure:
    @pytest.mark.parametrize("t,S,d", [
        (64, 256, 16),       # tiny engine config
        (128, 1024, 128),    # TPU-native
        (512, 8192, 128),    # 8k-context chunk
        (2048, 65536, 128),  # long-context chunk
        (4096, 131072, 128), # 128k-context chunk (Table 1's right edge)
    ])
    def test_vmem_fits_every_config(self, t, S, d):
        assert vmem_bytes(t, S, d) < VMEM_LIMIT

    def test_mxu_full_utilization_at_native_tiles(self):
        assert mxu_utilization_estimate(128, 1024, 128) == 1.0
        assert mxu_utilization_estimate(2048, 65536, 128) == 1.0

    def test_tiny_config_underutilizes_mxu(self):
        # head_dim=16 cannot fill the 128-wide systolic array — documented
        # limitation of the tiny validation model, not of the kernel.
        assert mxu_utilization_estimate(64, 256, 16) < 0.2

    def test_vmem_independent_of_context_beyond_block(self):
        # The BlockSpec streams K/V: footprint must NOT grow with S once
        # S >= block_k.
        assert vmem_bytes(128, 1024, 128) == vmem_bytes(128, 131072, 128)


class TestL2Census:
    def _hlo(self, stage):
        cfg = M.TinyConfig(n_layers=2)
        sds = jax.ShapeDtypeStruct
        if stage == "attn":
            hq, hkv = cfg.n_heads // 2, cfg.n_kv_heads // 2
            args = (
                sds((64, cfg.d_model), jnp.float32),
                sds((cfg.d_model,), jnp.float32),
                sds((cfg.d_model, hq * cfg.head_dim), jnp.float32),
                sds((cfg.d_model, hkv * cfg.head_dim), jnp.float32),
                sds((cfg.d_model, hkv * cfg.head_dim), jnp.float32),
                sds((hq * cfg.head_dim, cfg.d_model), jnp.float32),
                sds((hkv, cfg.max_seq, cfg.head_dim), jnp.float32),
                sds((hkv, cfg.max_seq, cfg.head_dim), jnp.float32),
                sds((), jnp.int32),
            )
            fn = M.make_attn_fn(cfg, 2)
        else:
            ff = cfg.d_ff // 2
            args = (
                sds((64, cfg.d_model), jnp.float32),
                sds((cfg.d_model,), jnp.float32),
                sds((cfg.d_model, ff), jnp.float32),
                sds((cfg.d_model, ff), jnp.float32),
                sds((ff, cfg.d_model), jnp.float32),
            )
            fn = M.make_mlp_fn(cfg)
        return aot.to_hlo_text(jax.jit(fn).lower(*args))

    @pytest.mark.parametrize("stage", ["attn", "mlp"])
    def test_no_collectives_in_stages(self, stage):
        text = self._hlo(stage)
        assert "all-reduce(" not in text
        assert "all-gather(" not in text

    def test_attn_stage_has_expected_gemms(self):
        text = self._hlo("attn")
        dots = len(re.findall(r"\sdot\(", text))
        # qkv (3) + o_proj (1) + flash-attention score/value matmuls (>=2)
        assert dots >= 6, f"expected >=6 dots, found {dots}"

    def test_mlp_stage_has_three_gemms(self):
        text = self._hlo("mlp")
        dots = len(re.findall(r"\sdot\(", text))
        assert dots == 3, f"gate+up+down should be 3 dots, found {dots}"
