//! placeholder — engine lands next
