# Repo entrypoints. `make artifacts` is the handoff between the python
# AOT layer and the rust engine (DESIGN.md §1): HLO text + weights +
# golden logits land in rust/artifacts, where cargo (cwd = rust/) finds
# them at "artifacts".

.PHONY: artifacts test bench clean

artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench collective
	cd rust && cargo bench --bench e2e_engine

clean:
	rm -rf rust/target rust/artifacts
