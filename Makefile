# Repo entrypoints. `make artifacts` is the handoff between the python
# AOT layer and the rust engine (DESIGN.md §1): HLO text + weights +
# golden logits land in rust/artifacts, where cargo (cwd = rust/) finds
# them at "artifacts".

.PHONY: artifacts test bench docs check-links clean

# Module invocation: aot.py uses package-relative imports, so it must
# run as `compile.aot`, not as a bare script.
artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench collective
	cd rust && cargo bench --bench e2e_engine
	cd rust && cargo bench --bench spec_decode

# API docs with the missing_docs gate CI enforces.
docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# README/DESIGN/EXPERIMENTS/ROADMAP links + `DESIGN.md §N` references.
check-links:
	python3 scripts/check_md_links.py

clean:
	rm -rf rust/target rust/artifacts
