//! END-TO-END VALIDATION DRIVER (DESIGN.md §7 "E2E").
//!
//! Exercises the full three-layer stack on a real workload: the rust
//! coordinator executes AOT-lowered JAX/Pallas artifacts across TP worker
//! threads with real ring collectives, under both the serial baseline
//! (paper Fig 1a) and ISO (Fig 1d). Two regimes are measured:
//!
//! * **native** — the ring runs at shared-memory speed. Comm is ~free
//!   relative to compute, i.e. the paper's "computation dominates" A800
//!   regime taken to the extreme: ISO's chunk-splitting overhead shows and
//!   the gain is small or negative — reproducing WHY the paper's A800
//!   numbers are modest.
//! * **emulated PCIe** — each ring hop is paced by the α+bytes/BW model at
//!   a bandwidth calibrated so comm ≈ compute (the 4090-with-int8 balance
//!   of Fig 2a). ISO then hides the collective behind compute and wins
//!   wallclock, and the int8 wire shrinks comm for real.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```text
//! make artifacts && cargo run --release --example iso_vs_serial
//! ```

use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::metrics::Histogram;

struct Row {
    ttft_mean: f64,
    ttft_p50: f64,
    overlap_eff: f64,
}

fn run(
    strategy: Strategy,
    tp: usize,
    quant: CommQuant,
    link_mbps: Option<f64>,
    prompts: &[Vec<i32>],
) -> anyhow::Result<Row> {
    let cfg = EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: quant,
        tp,
        max_chunk: 64,
        link_mbps,
        ..Default::default()
    };
    let mut engine = Engine::start(cfg)?;
    engine.prefill(&prompts[0])?; // warmup (first-execution costs)
    let mut ttft = Histogram::new();
    for p in prompts {
        ttft.record(engine.prefill(p)?.ttft_ms);
    }
    let report = engine.shutdown()?;
    let overlap_eff = report.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
        / report.workers.len() as f64;
    Ok(Row { ttft_mean: ttft.mean(), ttft_p50: ttft.p50(), overlap_eff })
}

fn main() -> anyhow::Result<()> {
    let prompt_len = 128;
    let n_requests = 10;
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|r| (0..prompt_len).map(|i| ((i * 31 + r * 17) % 512) as i32).collect())
        .collect();

    println!(
        "E2E: ISO vs serial on the real engine (tiny-gqa, {prompt_len}-token prompts, {n_requests} requests)\n"
    );
    println!(
        "{:<22} {:<4} {:<10} {:>11} {:>11} {:>9} {:>10}",
        "regime", "tp", "strategy", "ttft mean", "ttft p50", "ovl eff", "reduction"
    );

    // Regime 1: native shared-memory ring (compute dominates → paper's
    // A800-like behaviour, ISO gain ≈ 0 or negative).
    // Regime 2: emulated PCIe-class link calibrated so comm ≈ compute
    // (the 4090+int8 balance → ISO should win).
    for (regime, link, quant) in [
        ("native (comm≈free)", None, CommQuant::F32),
        ("emulated PCIe f32", Some(40.0), CommQuant::F32),
        ("emulated PCIe int8", Some(40.0), CommQuant::Int8),
    ] {
        for tp in [2usize, 4] {
            let serial = run(Strategy::Serial, tp, quant, link, &prompts)?;
            let iso = run(Strategy::Iso, tp, quant, link, &prompts)?;
            let reduction = (serial.ttft_mean - iso.ttft_mean) / serial.ttft_mean;
            println!(
                "{:<22} {:<4} {:<10} {:>9.1}ms {:>9.1}ms {:>9.2} {:>10}",
                regime, tp, "serial", serial.ttft_mean, serial.ttft_p50, serial.overlap_eff, "-"
            );
            println!(
                "{:<22} {:<4} {:<10} {:>9.1}ms {:>9.1}ms {:>9.2} {:>9.1}%",
                regime,
                tp,
                "iso",
                iso.ttft_mean,
                iso.ttft_p50,
                iso.overlap_eff,
                reduction * 100.0
            );
        }
        println!();
    }

    println!("native regime = paper's computation-dominates case (gain ≈ 0, §3.2/Fig 2b);");
    println!("emulated-PCIe = comm ≈ compute (Fig 2a after int8): ISO hides the collective.");
    println!("paper-scale Table-1 ratios: `cargo bench --bench table1`.");
    Ok(())
}
