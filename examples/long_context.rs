//! Long-context study: how the ISO gain evolves from 1k to 128k tokens,
//! with the compute/comm share analysis that drives Figure 2's asymmetric
//! regimes, plus a Figure-1-style Gantt of each strategy's first layers.
//!
//! ```text
//! cargo run --release --example long_context
//! ```

use iso::config::{SimExperiment, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::gantt;
use iso::sched::{build, reduction_vs_serial, run, Coster};
use iso::sim::OpKind;

fn main() {
    let platforms = [("4090", 4usize), ("a800", 4)];
    let model = ModelSpec::gqa_70b();

    println!("ISO gain and compute/comm balance vs context length — 70b GQA");
    println!(
        "{:<10} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "platform", "len", "compute/lyr", "comm/lyr", "comm share", "ISO gain"
    );
    for (gpu, cards) in platforms {
        for i in 0..8 {
            let len = 1024usize << i;
            let node = NodeProfile::by_name(gpu, cards).unwrap();
            let mut e = SimExperiment::new(node, model.clone(), len, Strategy::Iso);
            e.gemm_segments = if gpu == "a800" { 4 } else { 1 };
            let c = Coster::new(&e);
            let compute = c.attn_block_s(len, 0) + c.mlp_block_s(len);
            let comm = 2.0 * c.ar_s(len, 1);
            println!(
                "{:<10} {:>7}k {:>10.2}ms {:>10.2}ms {:>9.0}% {:>9.1}%",
                format!("{gpu}-{cards}"),
                len / 1024,
                compute * 1e3,
                comm * 1e3,
                comm / (comm + compute) * 100.0,
                reduction_vs_serial(&e) * 100.0
            );
        }
    }

    // Figure 1 style: timelines of the four pipelines on the same config.
    let node = NodeProfile::rtx4090(4);
    let len = 8192;
    println!("\nFigure 1 — first ~3 layers of each pipeline (30b, 4090-4, 8k prompt):");
    for strat in Strategy::all() {
        let e = SimExperiment::new(node.clone(), ModelSpec::mha_30b(), len, strat);
        let tl = run(&e);
        let graph = build(&e);
        let per_layer = tl.makespan_s / ModelSpec::mha_30b().n_layers as f64;
        println!("\n({strat})  makespan {:.0}ms, {} ops", tl.makespan_s * 1e3, graph.ops.len());
        print!("{}", gantt(&tl, 110, per_layer * 3.0));
        println!(
            "   busy: compute {:.0}ms, comm {:.0}ms, overlapped {:.0}ms",
            tl.busy_s(OpKind::Compute) * 1e3,
            tl.busy_s(OpKind::Comm) * 1e3,
            tl.overlap_s() * 1e3
        );
    }
}
