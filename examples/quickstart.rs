//! Quickstart: start the ISO engine on the tiny real model, serve a small
//! batch of requests, print latency/throughput.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::workload::{LenDist, TraceGen};

fn main() -> anyhow::Result<()> {
    // 1. Configure: 2-way tensor parallelism, ISO overlap, balanced split.
    let cfg = EngineConfig {
        strategy: Strategy::Iso,
        split: SplitPolicy::AttnBalanced,
        comm_quant: CommQuant::F32,
        tp: 2,
        max_chunk: 64,
        ..Default::default()
    };

    // 2. Start: compiles the AOT artifacts on each worker, loads weights.
    println!("starting engine (tp={}, strategy={}) ...", cfg.tp, cfg.strategy);
    let mut engine = Engine::start(cfg)?;
    let vocab = engine.manifest.config.vocab;

    // 3. Serve: a mixed batch of prompts, prefill + 4 decode steps each.
    let mut gen = TraceGen::new(42, vocab, LenDist::Bimodal {
        short: 48,
        long: 160,
        long_frac: 0.3,
    })
    .decode_steps(4);
    let requests = gen.generate(8);

    let t0 = std::time::Instant::now();
    let mut total_tokens = 0usize;
    for r in &requests {
        let out = engine.generate(&r.prompt, r.decode_steps)?;
        total_tokens += r.prompt.len() + out.tokens.len();
        println!(
            "req {:>2}: prompt={:>3} tok  ttft={:>7.1}ms  decoded={:?}",
            r.id,
            r.prompt.len(),
            out.ttft_ms,
            out.tokens
        );
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // 4. Report.
    let report = engine.shutdown()?;
    let mut m = report.metrics;
    println!("\n{}", m.report());
    println!(
        "throughput: {:.0} tok/s over {} requests ({:.2}s wall)",
        total_tokens as f64 / wall_s,
        requests.len(),
        wall_s
    );
    for w in &report.workers {
        println!(
            "rank {}: compute={:.0}ms stall={:.0}ms comm={:.0}ms overlap_eff={:.2}",
            w.rank,
            w.compute_ms,
            w.stall_ms,
            w.comm_ms,
            w.overlap_efficiency()
        );
    }
    Ok(())
}
