//! Regenerate the paper's Table 1 (and the §4.2 strategy comparison) from
//! the calibrated simulator; optionally dump CSV.
//!
//! ```text
//! cargo run --release --example paper_table1 [-- --csv out.csv]
//! ```

use iso::config::Strategy;
use iso::report::{render_table1, table1, table1_csv};

fn main() {
    let iso_rows = table1(Strategy::Iso);
    print!(
        "{}",
        render_table1(
            &iso_rows,
            "Table 1 — % decrease in prefill duration, ISO vs serial (simulated testbeds)",
        )
    );
    println!("paper:    4090 avg ≈35%  ·  A800 avg ≈15%  (≥4k prompts)\n");

    let gemm_rows = table1(Strategy::GemmOverlap);
    print!(
        "{}",
        render_table1(
            &gemm_rows,
            "§4.2 comparison — gemm-overlap vs serial (paper: 2–5% on A800, ≤0 on 4090)",
        )
    );

    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--csv") {
        let path = args.get(i + 1).map(String::as_str).unwrap_or("table1.csv");
        std::fs::write(path, table1_csv(&iso_rows)).expect("write csv");
        println!("\nwrote {path}");
    }
}
