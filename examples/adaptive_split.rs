//! Figure 3 / §6 study: sequence-split policies under attention imbalance.
//!
//! The causal mask makes the second half of a sequence markedly heavier;
//! this example quantifies the imbalance, shows where each policy puts the
//! split point, and measures the end-to-end effect in the simulator and on
//! the real CPU engine.
//!
//! ```text
//! make artifacts && cargo run --release --example adaptive_split
//! ```

use iso::config::{CommQuant, EngineConfig, SimExperiment, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::sched::prefill_s;
use iso::split::{attn_imbalance, choose_split, imbalance};

fn main() -> anyhow::Result<()> {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::gqa_70b();
    let policies = [
        ("even", SplitPolicy::Even),
        ("ratio:0.6", SplitPolicy::Ratio(0.6)),
        ("attn-balanced", SplitPolicy::AttnBalanced),
        ("adaptive(fig3)", SplitPolicy::AdaptiveAttnMlp),
    ];

    println!("split policies — 70b on 4090-4 (simulator)");
    println!(
        "{:<16} {:>8} {:>10} {:>12} {:>12} {:>12}",
        "policy", "len", "t0 frac", "chunk imbal", "attn imbal", "prefill"
    );
    for len in [4096usize, 16384, 65536] {
        for (name, p) in policies {
            let s = choose_split(p, &node, &model, len);
            let mut e = SimExperiment::new(node.clone(), model.clone(), len, Strategy::Iso);
            e.split = p;
            println!(
                "{:<16} {:>7}k {:>9.2} {:>11.1}% {:>11.1}% {:>10.1}ms",
                name,
                len / 1024,
                s.t0 as f64 / len as f64,
                imbalance(&node, &model, &s) * 100.0,
                attn_imbalance(&node, &model, &s) * 100.0,
                prefill_s(&e) * 1e3
            );
        }
        println!();
    }

    // Real engine: same policies, measured TTFT (tiny model, CPU).
    if iso::runtime::Manifest::load("artifacts").is_ok() {
        println!("split policies — real engine TTFT (tiny-gqa, tp=2, 192-token prompts)");
        let prompt: Vec<i32> = (0..192).map(|i| ((i * 29) % 512) as i32).collect();
        for (name, p) in [
            ("even", SplitPolicy::Even),
            ("ratio:0.6", SplitPolicy::Ratio(0.6)),
            ("attn-balanced", SplitPolicy::AttnBalanced),
        ] {
            let cfg = EngineConfig {
                strategy: Strategy::Iso,
                split: p,
                comm_quant: CommQuant::F32,
                tp: 2,
                max_chunk: 64,
                ..Default::default()
            };
            let mut engine = Engine::start(cfg)?;
            engine.prefill(&prompt)?; // warmup
            let mut mean = 0.0;
            let n = 6;
            for _ in 0..n {
                mean += engine.prefill(&prompt)?.ttft_ms;
            }
            engine.shutdown()?;
            println!("  {:<16} ttft mean {:>8.1}ms", name, mean / n as f64);
        }
    } else {
        println!("(skip engine half: run `make artifacts` first)");
    }
    Ok(())
}
