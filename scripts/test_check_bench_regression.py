#!/usr/bin/env python3
"""Unit tests for check_bench_regression.py (PR-10 satellite).

The gate grew two load-bearing behaviors that deserve their own tests:
the repeatable `--snapshot` merge (later files' sections override
earlier ones — the CI gate feeds one file per PR sweep), and the
"deterministic `sim_*` section missing from the baseline" failure. Both
are exercised end-to-end through the CLI with real temp files, stdlib
only — run directly (`python3 scripts/test_check_bench_regression.py`)
or via unittest discovery.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "check_bench_regression.py")


def rec(case, mean_ms, **extra):
    r = {"case": case, "mean_ms": mean_ms}
    r.update(extra)
    return r


class GateHarness(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()

    def tearDown(self):
        self.dir.cleanup()

    def write(self, name, payload):
        path = os.path.join(self.dir.name, name)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f)
        return path

    def run_gate(self, baseline, snapshots):
        cmd = [sys.executable, SCRIPT, "--baseline", baseline]
        for s in snapshots:
            cmd += ["--snapshot", s]
        return subprocess.run(cmd, capture_output=True, text=True)

    # ----------------------------------------------- snapshot merging --

    def test_sections_merge_across_snapshot_files(self):
        # The baseline's sections may be split across per-PR snapshot
        # files; the gate must see their union.
        baseline = self.write("baseline.json", {
            "sim_a": [rec("a1", 100.0)],
            "sim_b": [rec("b1", 50.0)],
        })
        snap_a = self.write("snap_a.json", {"sim_a": [rec("a1", 101.0)]})
        snap_b = self.write("snap_b.json", {"sim_b": [rec("b1", 49.0)]})
        out = self.run_gate(baseline, [snap_a, snap_b])
        self.assertEqual(out.returncode, 0, out.stdout + out.stderr)
        # Either file alone leaves the other section missing → failure.
        out = self.run_gate(baseline, [snap_a])
        self.assertEqual(out.returncode, 1)
        self.assertIn("sim_b: section missing from snapshot", out.stdout)

    def test_later_snapshot_file_overrides_earlier_section(self):
        # dict.update semantics at section granularity: a regressed copy
        # of sim_a in the first file is shadowed by the healthy copy in
        # the second — and vice versa.
        baseline = self.write("baseline.json", {"sim_a": [rec("a1", 100.0)]})
        regressed = self.write("regressed.json", {"sim_a": [rec("a1", 200.0)]})
        healthy = self.write("healthy.json", {"sim_a": [rec("a1", 100.0)]})
        out = self.run_gate(baseline, [regressed, healthy])
        self.assertEqual(out.returncode, 0, out.stdout + out.stderr)
        out = self.run_gate(baseline, [healthy, regressed])
        self.assertEqual(out.returncode, 1)
        self.assertIn("REGRESSION sim_a/a1 mean_ms", out.stdout)

    # ------------------------------------- sim_* baseline completeness --

    def test_sim_section_missing_from_baseline_fails(self):
        # Deterministic simulator sections must be gated: a new sim_*
        # section that nobody added to BENCH_BASELINE.json is a failure,
        # not a silent skip — even when everything else is clean.
        baseline = self.write("baseline.json", {"sim_a": [rec("a1", 100.0)]})
        snap = self.write("snap.json", {
            "sim_a": [rec("a1", 100.0)],
            "sim_tune": [rec("t1", 10.0)],
        })
        out = self.run_gate(baseline, [snap])
        self.assertEqual(out.returncode, 1)
        self.assertIn("sim_tune: sim section missing from baseline", out.stdout)

    def test_engine_sections_stay_ungated(self):
        # Artifact-gated engine sections vary by machine and are ignored
        # when absent from the baseline.
        baseline = self.write("baseline.json", {"sim_a": [rec("a1", 100.0)]})
        snap = self.write("snap.json", {
            "sim_a": [rec("a1", 100.0)],
            "e2e_engine_tune": [rec("t1", 10.0)],
        })
        out = self.run_gate(baseline, [snap])
        self.assertEqual(out.returncode, 0, out.stdout + out.stderr)

    # -------------------------------------------- direction & tolerance --

    def test_direction_aware_tolerance(self):
        baseline = self.write("baseline.json", {
            "sim_a": [rec("a1", 100.0, pred_tok_s=1000.0)],
        })
        within = self.write("within.json", {
            "sim_a": [rec("a1", 109.0, pred_tok_s=910.0)],
        })
        out = self.run_gate(baseline, [within])
        self.assertEqual(out.returncode, 0, out.stdout + out.stderr)
        slow = self.write("slow.json", {
            "sim_a": [rec("a1", 100.0, pred_tok_s=800.0)],
        })
        out = self.run_gate(baseline, [slow])
        self.assertEqual(out.returncode, 1)
        self.assertIn("pred_tok_s", out.stdout)

    def test_ungated_keys_do_not_trip(self):
        # Identity/context keys (rank, tau, …) carry no direction and
        # may move freely.
        baseline = self.write("baseline.json", {
            "sim_a": [rec("a1", 100.0, rank=1.0, tau=1.0)],
        })
        snap = self.write("snap.json", {
            "sim_a": [rec("a1", 100.0, rank=5.0, tau=-1.0)],
        })
        out = self.run_gate(baseline, [snap])
        self.assertEqual(out.returncode, 0, out.stdout + out.stderr)

    def test_vanished_case_is_a_regression(self):
        baseline = self.write("baseline.json", {
            "sim_a": [rec("a1", 100.0), rec("a2", 100.0)],
        })
        snap = self.write("snap.json", {"sim_a": [rec("a1", 100.0)]})
        out = self.run_gate(baseline, [snap])
        self.assertEqual(out.returncode, 1)
        self.assertIn("sim_a/a2: case missing from snapshot", out.stdout)


if __name__ == "__main__":
    unittest.main(verbosity=2)
