#!/usr/bin/env python3
"""Markdown link checker for the repo's documentation set.

Checks, offline by default:
  * inline links `[text](target)` in the checked files: relative targets
    must exist on disk (anchors into .md targets must match a heading);
    http(s) targets are syntax-checked (HEAD-requested only with
    CHECK_EXTERNAL=1);
  * `DESIGN.md §N` cross-references — in the checked files AND in rust
    sources/benches/tests — must name a real `## §N` section of
    DESIGN.md.

Exit code 0 = clean, 1 = broken references (each printed).
Run from the repo root: `python3 scripts/check_md_links.py`.
"""

import os
import re
import sys

CHECKED_MD = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md", "TUNING.md"]
RUST_DIRS = ["rust/src", "rust/benches", "rust/tests", "examples"]

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
SECTION_REF_RE = re.compile(r"DESIGN\.md\s+§§?([0-9]+(?:[-–,]\s*[0-9]+)*)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def heading_anchor(text):
    """GitHub-style anchor slug for a heading."""
    slug = text.strip().lower()
    slug = re.sub(r"[^\w\s-]", "", slug, flags=re.UNICODE)
    slug = re.sub(r"\s+", "-", slug)
    return slug


def design_sections(root):
    path = os.path.join(root, "DESIGN.md")
    if not os.path.exists(path):
        return set()
    text = open(path, encoding="utf-8").read()
    return set(int(m) for m in re.findall(r"^##\s+§(\d+)\b", text, re.M))


def expand_ref_numbers(spec):
    """'1-8' / '4, 6' / '9' → the referenced section numbers."""
    nums = []
    for part in re.split(r"[,]", spec):
        part = part.strip()
        m = re.match(r"^(\d+)\s*[-–]\s*(\d+)$", part)
        if m:
            nums.extend(range(int(m.group(1)), int(m.group(2)) + 1))
        elif part:
            nums.append(int(part))
    return nums


def check_inline_links(root, md, errors):
    path = os.path.join(root, md)
    text = open(path, encoding="utf-8").read()
    for lineno, line in enumerate(text.split("\n"), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://")):
                if not re.match(r"^https?://[\w.-]+(/\S*)?$", target):
                    errors.append(f"{md}:{lineno}: malformed URL {target!r}")
                elif os.environ.get("CHECK_EXTERNAL") == "1":
                    import urllib.request

                    req = urllib.request.Request(target, method="HEAD")
                    try:
                        urllib.request.urlopen(req, timeout=10)
                    except Exception as e:  # noqa: BLE001 - report, don't crash
                        errors.append(f"{md}:{lineno}: unreachable {target} ({e})")
                continue
            if target.startswith("mailto:"):
                continue
            rel, _, anchor = target.partition("#")
            if rel:
                dest = os.path.normpath(os.path.join(root, os.path.dirname(md), rel))
                if not os.path.exists(dest):
                    errors.append(f"{md}:{lineno}: missing file {rel!r}")
                    continue
            else:
                dest = path
            if anchor and dest.endswith(".md"):
                headings = HEADING_RE.findall(open(dest, encoding="utf-8").read())
                anchors = {heading_anchor(h) for h in headings}
                if anchor not in anchors:
                    errors.append(f"{md}:{lineno}: missing anchor #{anchor} in {rel or md}")


def check_section_refs(root, sections, errors):
    files = [os.path.join(root, md) for md in CHECKED_MD if os.path.exists(os.path.join(root, md))]
    for d in RUST_DIRS:
        full = os.path.join(root, d)
        for dirpath, _, names in os.walk(full):
            for n in names:
                if n.endswith(".rs"):
                    files.append(os.path.join(dirpath, n))
    for f in files:
        text = open(f, encoding="utf-8").read()
        rel = os.path.relpath(f, root)
        for lineno, line in enumerate(text.split("\n"), 1):
            for spec in SECTION_REF_RE.findall(line):
                for n in expand_ref_numbers(spec):
                    if n not in sections:
                        errors.append(
                            f"{rel}:{lineno}: DESIGN.md §{n} does not exist "
                            f"(have §{{{', '.join(map(str, sorted(sections)))}}})"
                        )


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors = []
    sections = design_sections(root)
    if not sections:
        errors.append("DESIGN.md has no `## §N` sections (or is missing)")
    for md in CHECKED_MD:
        if not os.path.exists(os.path.join(root, md)):
            errors.append(f"checked file missing: {md}")
            continue
        check_inline_links(root, md, errors)
    check_section_refs(root, sections, errors)
    if errors:
        print(f"FAIL: {len(errors)} broken reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"OK: {', '.join(CHECKED_MD)} + rust sources "
          f"(DESIGN.md sections: §{min(sections)}–§{max(sections)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
