#!/usr/bin/env python3
"""Bench-regression gate (PR-4 satellite).

Compares the bench-smoke snapshot (`BENCH_PR4.json`, written by
`cargo bench --bench e2e_engine`) against the committed
`BENCH_BASELINE.json` and fails on regression beyond a tolerance band
(default ±10%).

Semantics:
  * every section/case present in the BASELINE must exist in the
    snapshot — a vanished case is a regression (the bench silently
    stopped measuring it);
  * metric direction is inferred from its name: `*tok_s*` is
    higher-is-better, `*_ms*` / `*exposed*` are lower-is-better; other
    keys (`case`, `pp`, `tp`, `bubble_frac`, …) are identity/context and
    not gated;
  * extra sections or cases in the snapshot (e.g. the artifact-gated
    engine sweeps on a machine with `make artifacts`) are ignored, so
    the committed baseline only needs the deterministic simulator
    sections that CI reproduces — EXCEPT `sim_*` sections, which are
    deterministic by construction: a `sim_*` section present in a
    snapshot but absent from the baseline fails with a clear message
    (PR-7 satellite; previously the new section was silently ungated);
  * a zero baseline for a lower-is-better metric demands the snapshot
    stay ~zero (absolute epsilon); for higher-is-better it always
    passes.

Exit 0 = within tolerance, 1 = regression (each printed). Run from the
repo root; `--snapshot` is repeatable and the files' sections are merged
(the baseline's sections may be split across per-PR snapshots, e.g.
`sim_pp` in BENCH_PR4.json and `sim_fused_epilogue` in BENCH_PR5.json):

    python3 scripts/check_bench_regression.py \
        --baseline BENCH_BASELINE.json \
        --snapshot BENCH_PR4.json --snapshot BENCH_PR5.json

To refresh the baseline after an intentional perf change, re-run the
bench and copy the gated sections over (`--update` prints the snapshot's
gated sections in baseline form).
"""

import argparse
import json
import sys

ABS_EPS = 1e-9


def direction(metric):
    """'higher' / 'lower' / None (not gated) for a metric name."""
    if "tok_s" in metric:
        return "higher"
    if "_ms" in metric or "exposed" in metric:
        return "lower"
    return None


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except FileNotFoundError:
        print(f"REGRESSION: {path} not found")
        sys.exit(1)
    except json.JSONDecodeError as e:
        print(f"REGRESSION: {path} is not valid JSON: {e}")
        sys.exit(1)


def by_case(records):
    return {r.get("case"): r for r in records if isinstance(r, dict)}


def gate(baseline, snapshot, tol):
    failures = []
    compared = 0
    for section, snap_records in snapshot.items():
        if (
            section.startswith("sim_")
            and isinstance(snap_records, list)
            and section not in baseline
        ):
            failures.append(
                f"{section}: sim section missing from baseline — deterministic "
                "simulator sections must be gated (add it to BENCH_BASELINE.json)"
            )
    for section, base_records in baseline.items():
        snap_records = snapshot.get(section)
        if not isinstance(base_records, list):
            continue
        if not isinstance(snap_records, list):
            failures.append(f"{section}: section missing from snapshot")
            continue
        snap_by_case = by_case(snap_records)
        for base in base_records:
            case = base.get("case")
            snap = snap_by_case.get(case)
            if snap is None:
                failures.append(f"{section}/{case}: case missing from snapshot")
                continue
            for metric, base_val in base.items():
                d = direction(metric)
                if d is None or not isinstance(base_val, (int, float)):
                    continue
                new_val = snap.get(metric)
                if not isinstance(new_val, (int, float)):
                    failures.append(f"{section}/{case}: metric {metric} missing")
                    continue
                compared += 1
                if base_val == 0:
                    ok = d == "higher" or abs(new_val) <= ABS_EPS
                    delta = "n/a"
                elif d == "higher":
                    ok = new_val >= base_val * (1.0 - tol)
                    delta = f"{(new_val / base_val - 1.0) * 100:+.1f}%"
                else:
                    ok = new_val <= base_val * (1.0 + tol)
                    delta = f"{(new_val / base_val - 1.0) * 100:+.1f}%"
                line = (
                    f"{section}/{case} {metric}: {base_val:.6g} -> "
                    f"{new_val:.6g} ({delta}, {d}-is-better)"
                )
                if ok:
                    print(f"OK         {line}")
                else:
                    failures.append(line)
    if compared == 0:
        failures.append(
            "gate is vacuous: no baseline metric could be compared "
            "(empty baseline or snapshot sections renamed?)"
        )
    return failures


def print_update(baseline, snapshot):
    out = {}
    for section, base_records in baseline.items():
        snap_records = snapshot.get(section, [])
        snap_by_case = by_case(snap_records)
        rows = []
        for base in base_records:
            snap = snap_by_case.get(base.get("case"))
            if snap is None:
                continue
            rows.append(
                {
                    k: snap.get(k, v)
                    for k, v in base.items()
                }
            )
        out[section] = rows
    print(json.dumps(out, indent=2))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_BASELINE.json")
    ap.add_argument(
        "--snapshot",
        action="append",
        help="snapshot file; repeatable — sections from later files merge "
        "over earlier ones (default: BENCH_PR4.json)",
    )
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument(
        "--update",
        action="store_true",
        help="print the snapshot's gated sections in baseline form and exit",
    )
    args = ap.parse_args()

    baseline = load(args.baseline)
    snapshot = {}
    for path in args.snapshot or ["BENCH_PR4.json"]:
        snapshot.update(load(path))
    if args.update:
        print_update(baseline, snapshot)
        return

    failures = gate(baseline, snapshot, args.tolerance)
    if failures:
        print(f"\n{len(failures)} bench regression(s) beyond ±{args.tolerance:.0%}:")
        for f in failures:
            print(f"REGRESSION {f}")
        sys.exit(1)
    print(f"\nbench gate clean (tolerance ±{args.tolerance:.0%})")


if __name__ == "__main__":
    main()
